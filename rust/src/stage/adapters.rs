//! [`Stage`](super::Stage) adapters for every existing kernel — the f32
//! stages and their fixed-point images, each reproducing the legacy
//! fused datapath's per-row arithmetic exactly (see the bit-identity
//! tests in `tests/stage_graph_identity.rs`).
//!
//! Training-path emission rules (what a downstream stage trains on):
//!
//! * static stages (RP, DCT, identity) emit their forward transform;
//! * the GHA whitener emits the whitened row computed *after* that
//!   row's update, clamped to ±4σ — exactly the staging the fused
//!   `DrUnit`/`FxpDrUnit` performed between its two halves;
//! * the EASI rotation emits its (post-update) forward transform, and
//!   gates its own updates behind the whiten-only warm-up using a
//!   sample counter that tracks the full stream (including rows seen
//!   while the stage was muxed out), matching the fused units' gate on
//!   the whitener's global step count.

use super::{resize_f32, Stage, StageRole, StageState};
use crate::easi::EasiTrainer;
use crate::fxp::kernels::resize_buf;
use crate::fxp::{FxpConst, FxpEasiRot, FxpGha, FxpMat, FxpRp, FxpSpec};
use crate::gha::GhaWhitener;
use crate::linalg::Mat;
use crate::pca::dct::Dct1d;
use crate::pca::BatchPca;
use crate::rp::RandomProjection;
use anyhow::ensure;

// --------------------------------------------------------------- f32

/// Random-projection front end (f32 backend). Static: training is a
/// pass-through of the forward transform. The dense scaled matrix is
/// materialised once at construction (bulk forwards and reports reuse
/// it instead of re-densifying per call).
pub struct RpStage {
    pub rp: RandomProjection,
    pub dense: Mat,
}

impl RpStage {
    pub fn new(rp: RandomProjection) -> Self {
        let dense = rp.to_dense();
        Self { rp, dense }
    }
}

impl Stage for RpStage {
    fn name(&self) -> &'static str {
        "rp"
    }

    fn role(&self) -> StageRole {
        StageRole::Rp
    }

    fn in_dim(&self) -> usize {
        self.rp.in_dim
    }

    fn out_dim(&self) -> usize {
        self.rp.out_dim
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        if let Some(o) = out {
            self.transform_tile(x, rows, o);
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (m, p) = (self.rp.in_dim, self.rp.out_dim);
        assert_eq!(x.len(), rows * m, "rp stage tile shape mismatch");
        resize_f32(out, rows * p);
        for r in 0..rows {
            self.rp
                .apply_into(&x[r * m..(r + 1) * m], &mut out[r * p..(r + 1) * p]);
        }
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.dense.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// GHA whitening stage (f32 backend). Emits post-update whitened rows
/// clamped to ±4 (the σ = 1 domain), as the fused `DrUnit` staged them
/// for its rotation half.
pub struct GhaStage {
    pub gha: GhaWhitener,
}

impl GhaStage {
    pub fn new(gha: GhaWhitener) -> Self {
        Self { gha }
    }
}

impl Stage for GhaStage {
    fn name(&self) -> &'static str {
        "whiten:gha"
    }

    fn role(&self) -> StageRole {
        StageRole::Whiten
    }

    fn in_dim(&self) -> usize {
        self.gha.config.input_dim
    }

    fn out_dim(&self) -> usize {
        self.gha.config.output_dim
    }

    fn is_adaptive(&self) -> bool {
        true
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        let (m, n) = (self.gha.config.input_dim, self.gha.config.output_dim);
        assert_eq!(x.len(), rows * m, "gha stage tile shape mismatch");
        match out {
            Some(o) => {
                resize_f32(o, rows * n);
                for r in 0..rows {
                    let row = &x[r * m..(r + 1) * m];
                    self.gha.step(row);
                    let orow = &mut o[r * n..(r + 1) * n];
                    self.gha.whiten_into(row, orow);
                    for v in orow.iter_mut() {
                        *v = v.clamp(-4.0, 4.0);
                    }
                }
            }
            None => {
                for r in 0..rows {
                    self.gha.step(&x[r * m..(r + 1) * m]);
                }
            }
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (m, n) = (self.gha.config.input_dim, self.gha.config.output_dim);
        assert_eq!(x.len(), rows * m, "gha stage tile shape mismatch");
        resize_f32(out, rows * n);
        for r in 0..rows {
            self.gha
                .whiten_into(&x[r * m..(r + 1) * m], &mut out[r * n..(r + 1) * n]);
        }
    }

    fn update_magnitude(&self) -> Option<f64> {
        Some(self.gha.orthonormality_error())
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.gha.whitening_matrix())
    }

    fn save_state(&self) -> StageState {
        StageState {
            mats: vec![self.gha.subspace().clone()],
            vecs: vec![self.gha.variances().to_vec()],
            counters: vec![self.gha.steps()],
            ..StageState::default()
        }
    }

    fn restore_state(&mut self, st: &StageState) -> anyhow::Result<()> {
        ensure!(
            st.mats.len() == 1 && st.vecs.len() == 1 && st.counters.len() == 1,
            "gha stage state shape"
        );
        self.gha
            .set_state(st.mats[0].clone(), st.vecs[0].clone(), st.counters[0]);
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// EASI stage (f32 backend): the square rotation of the composed unit
/// or the standalone (possibly rectangular) EASI trainer, depending on
/// construction. Carries the warm-up gate, the periodic retraction of
/// the unit's rotation, and the reconfiguration mux.
pub struct EasiStage {
    pub trainer: EasiTrainer,
    label: &'static str,
    warmup: u64,
    seen: u64,
    retract_every: Option<u64>,
    active: bool,
}

impl EasiStage {
    pub fn new(
        trainer: EasiTrainer,
        label: &'static str,
        warmup: u64,
        retract_every: Option<u64>,
    ) -> Self {
        Self {
            trainer,
            label,
            warmup,
            seen: 0,
            retract_every,
            active: true,
        }
    }

    fn train_row(&mut self, row: &[f32]) {
        self.seen += 1;
        if self.active && self.seen > self.warmup {
            self.trainer.step(row);
            if let Some(k) = self.retract_every {
                if self.trainer.steps() % k == 0 {
                    self.trainer.reorthonormalize();
                }
            }
        }
    }
}

impl Stage for EasiStage {
    fn name(&self) -> &'static str {
        self.label
    }

    fn role(&self) -> StageRole {
        StageRole::Rot
    }

    fn in_dim(&self) -> usize {
        self.trainer.config.input_dim
    }

    fn out_dim(&self) -> usize {
        self.trainer.config.output_dim
    }

    fn is_adaptive(&self) -> bool {
        true
    }

    fn bypassed(&self) -> bool {
        !self.active
    }

    fn set_active(&mut self, on: bool) {
        self.active = on;
    }

    fn advance(&mut self, rows: usize) {
        self.seen += rows as u64;
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        let (m, n) = (self.trainer.config.input_dim, self.trainer.config.output_dim);
        assert_eq!(x.len(), rows * m, "easi stage tile shape mismatch");
        match out {
            Some(o) => {
                resize_f32(o, rows * n);
                for r in 0..rows {
                    let row = &x[r * m..(r + 1) * m];
                    self.train_row(row);
                    let y = self.trainer.transform(row);
                    o[r * n..(r + 1) * n].copy_from_slice(&y);
                }
            }
            None => {
                for r in 0..rows {
                    self.train_row(&x[r * m..(r + 1) * m]);
                }
            }
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (m, n) = (self.trainer.config.input_dim, self.trainer.config.output_dim);
        assert_eq!(x.len(), rows * m, "easi stage tile shape mismatch");
        resize_f32(out, rows * n);
        for r in 0..rows {
            let y = self.trainer.transform(&x[r * m..(r + 1) * m]);
            out[r * n..(r + 1) * n].copy_from_slice(&y);
        }
    }

    fn update_magnitude(&self) -> Option<f64> {
        Some(self.trainer.update_magnitude())
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.trainer.separation_matrix().clone())
    }

    fn save_state(&self) -> StageState {
        StageState {
            mats: vec![self.trainer.separation_matrix().clone()],
            counters: vec![self.trainer.steps(), self.seen],
            ..StageState::default()
        }
    }

    fn restore_state(&mut self, st: &StageState) -> anyhow::Result<()> {
        ensure!(
            st.mats.len() == 1 && st.counters.len() == 2,
            "easi stage state shape"
        );
        self.trainer.set_separation_matrix(st.mats[0].clone());
        self.trainer.set_steps(st.counters[0]);
        self.seen = st.counters[1];
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Batch-PCA stage (f32 only): fits on the full staged training matrix
/// before any streaming, then transforms like a static stage.
pub struct PcaStage {
    pca: Option<BatchPca>,
    whiten: bool,
    in_dim: usize,
    out_dim: usize,
}

impl PcaStage {
    pub fn new(in_dim: usize, out_dim: usize, whiten: bool) -> Self {
        Self {
            pca: None,
            whiten,
            in_dim,
            out_dim,
        }
    }

    fn fitted(&self) -> &BatchPca {
        self.pca.as_ref().expect("pca stage used before fit")
    }
}

impl Stage for PcaStage {
    fn name(&self) -> &'static str {
        if self.whiten {
            "pca:whiten"
        } else {
            "pca"
        }
    }

    fn role(&self) -> StageRole {
        StageRole::Whiten
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn is_batch(&self) -> bool {
        true
    }

    fn is_affine(&self) -> bool {
        true
    }

    fn fit_batch(&mut self, x: &Mat) {
        assert_eq!(x.cols_count(), self.in_dim, "pca stage fit shape mismatch");
        self.pca = Some(BatchPca::fit(x, self.out_dim));
    }

    fn batch_fitted(&self) -> bool {
        self.pca.is_some()
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        if let Some(o) = out {
            self.transform_tile(x, rows, o);
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (m, n) = (self.in_dim, self.out_dim);
        assert_eq!(x.len(), rows * m, "pca stage tile shape mismatch");
        resize_f32(out, rows * n);
        let pca = self.fitted();
        for r in 0..rows {
            let row = &x[r * m..(r + 1) * m];
            let y = if self.whiten {
                pca.whiten(row)
            } else {
                pca.transform(row)
            };
            out[r * n..(r + 1) * n].copy_from_slice(&y);
        }
    }

    /// The *linear part* of the affine PCA map (the mean offset is not
    /// representable in a matrix fold) — reporting only; bulk forwards
    /// detect [`Stage::is_affine`] and take the sequential chain.
    fn dense_matrix(&self) -> Option<Mat> {
        self.pca.as_ref().map(|p| {
            if self.whiten {
                p.whitening.clone()
            } else {
                p.components.clone()
            }
        })
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Fixed 1-D DCT truncation stage (f32 backend).
pub struct DctStage {
    pub dct: Dct1d,
    in_dim: usize,
    out_dim: usize,
}

impl DctStage {
    pub fn new(in_dim: usize, out_dim: usize) -> Self {
        Self {
            dct: Dct1d::new(in_dim, out_dim),
            in_dim,
            out_dim,
        }
    }
}

impl Stage for DctStage {
    fn name(&self) -> &'static str {
        "dct"
    }

    fn role(&self) -> StageRole {
        StageRole::Rp
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        if let Some(o) = out {
            self.transform_tile(x, rows, o);
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (m, n) = (self.in_dim, self.out_dim);
        assert_eq!(x.len(), rows * m, "dct stage tile shape mismatch");
        resize_f32(out, rows * n);
        for r in 0..rows {
            let y = self.dct.transform(&x[r * m..(r + 1) * m]);
            out[r * n..(r + 1) * n].copy_from_slice(&y);
        }
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.dct.matrix().clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Pass-through stage (both backends). In a fixed-point graph it
/// carries its boundary format so graph requantization stays explicit.
pub struct IdentityStage {
    dim: usize,
    spec: Option<FxpSpec>,
}

impl IdentityStage {
    pub fn new(dim: usize, spec: Option<FxpSpec>) -> Self {
        Self { dim, spec }
    }
}

impl Stage for IdentityStage {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn role(&self) -> StageRole {
        StageRole::Rp
    }

    fn in_dim(&self) -> usize {
        self.dim
    }

    fn out_dim(&self) -> usize {
        self.dim
    }

    fn step_tile(&mut self, x: &[f32], rows: usize, out: Option<&mut Vec<f32>>) {
        if let Some(o) = out {
            self.transform_tile(x, rows, o);
        }
    }

    fn transform_tile(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        assert_eq!(x.len(), rows * self.dim, "identity stage tile shape");
        resize_f32(out, x.len());
        out.copy_from_slice(x);
    }

    fn input_spec(&self) -> Option<FxpSpec> {
        self.spec
    }

    fn output_spec(&self) -> Option<FxpSpec> {
        self.spec
    }

    fn step_tile_raw(&mut self, x: &[i32], rows: usize, out: Option<&mut Vec<i32>>) {
        if let Some(o) = out {
            self.transform_tile_raw(x, rows, o);
        }
    }

    fn transform_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        assert_eq!(x.len(), rows * self.dim, "identity stage tile shape");
        resize_buf(out, x.len());
        out.copy_from_slice(x);
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(Mat::eye(self.dim, self.dim))
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// --------------------------------------------------------- raw words

/// Quantized random-projection front end. Keeps the f32 projection it
/// was quantized from (and its dense image, materialised once) for
/// reports (`rp_matrix`, artifact export).
pub struct FxpRpStage {
    pub rp_f32: RandomProjection,
    pub rp: FxpRp,
    pub dense: Mat,
}

impl FxpRpStage {
    pub fn new(rp_f32: RandomProjection, spec: FxpSpec) -> Self {
        let rp = FxpRp::from_rp(&rp_f32, spec);
        let dense = rp_f32.to_dense();
        Self { rp_f32, rp, dense }
    }
}

impl Stage for FxpRpStage {
    fn name(&self) -> &'static str {
        "rp"
    }

    fn role(&self) -> StageRole {
        StageRole::Rp
    }

    fn in_dim(&self) -> usize {
        self.rp.in_dim
    }

    fn out_dim(&self) -> usize {
        self.rp.out_dim
    }

    fn input_spec(&self) -> Option<FxpSpec> {
        Some(self.rp.spec)
    }

    fn output_spec(&self) -> Option<FxpSpec> {
        Some(self.rp.spec)
    }

    fn step_tile_raw(&mut self, x: &[i32], rows: usize, out: Option<&mut Vec<i32>>) {
        if let Some(o) = out {
            self.rp.apply_tile_raw(x, rows, o);
        }
    }

    fn transform_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        self.rp.apply_tile_raw(x, rows, out);
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.dense.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Quantized GHA whitening stage. Emits post-update whitened rows
/// clamped to ±4σ in its own format — the fused `FxpDrUnit` staging;
/// the graph's boundary requantization then maps them into the next
/// stage's format, completing the legacy per-element sequence.
pub struct FxpGhaStage {
    pub gha: FxpGha,
    clamp_raw: i32,
}

impl FxpGhaStage {
    /// `gha` must already carry its σ target (the builder sets the
    /// sigma shift from the narrower of this stage's and any downstream
    /// rotation's formats before constructing the stage).
    pub fn new(gha: FxpGha) -> Self {
        let clamp_raw = gha.spec.quantize(4.0 * gha.target_sigma());
        Self { gha, clamp_raw }
    }
}

impl Stage for FxpGhaStage {
    fn name(&self) -> &'static str {
        "whiten:gha"
    }

    fn role(&self) -> StageRole {
        StageRole::Whiten
    }

    fn in_dim(&self) -> usize {
        self.gha.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.gha.output_dim()
    }

    fn is_adaptive(&self) -> bool {
        true
    }

    fn input_spec(&self) -> Option<FxpSpec> {
        Some(self.gha.spec)
    }

    fn output_spec(&self) -> Option<FxpSpec> {
        Some(self.gha.spec)
    }

    fn step_tile_raw(&mut self, x: &[i32], rows: usize, out: Option<&mut Vec<i32>>) {
        let (m, n) = (self.gha.input_dim(), self.gha.output_dim());
        assert_eq!(x.len(), rows * m, "fxp gha stage tile shape mismatch");
        match out {
            Some(o) => {
                resize_buf(o, rows * n);
                for r in 0..rows {
                    let row = &x[r * m..(r + 1) * m];
                    self.gha.step_raw(row);
                    let orow = &mut o[r * n..(r + 1) * n];
                    self.gha.whiten_into(row, orow);
                    for v in orow.iter_mut() {
                        *v = (*v).clamp(-self.clamp_raw, self.clamp_raw);
                    }
                }
            }
            None => self.gha.step_tile_raw(x, rows),
        }
    }

    fn transform_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        self.gha.whiten_tile_raw(x, rows, out);
    }

    fn update_magnitude(&self) -> Option<f64> {
        Some(self.gha.orthonormality_error())
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.gha.whitening_matrix())
    }

    fn save_state(&self) -> StageState {
        let (w, var_acc, steps, coeff, shadow) = self.gha.save_state();
        // The block-scaled coefficients ride in two word buffers (raw
        // mantissas + fraction counts).
        let coeff_raw: Vec<i32> = coeff.iter().map(|c| c.raw).collect();
        let coeff_frac: Vec<i32> = coeff.iter().map(|c| c.frac as i32).collect();
        StageState {
            mats: shadow.into_iter().collect(),
            words: vec![w, coeff_raw, coeff_frac],
            wide: vec![var_acc],
            counters: vec![steps],
            ..StageState::default()
        }
    }

    fn restore_state(&mut self, st: &StageState) -> anyhow::Result<()> {
        ensure!(
            st.words.len() == 3 && st.wide.len() == 1 && st.counters.len() == 1,
            "fxp gha stage state shape"
        );
        ensure!(
            st.words[1].len() == st.words[2].len(),
            "fxp gha stage coefficient state shape"
        );
        let coeff: Vec<FxpConst> = st.words[1]
            .iter()
            .zip(&st.words[2])
            .map(|(&raw, &frac)| FxpConst {
                raw,
                frac: frac as u8,
            })
            .collect();
        self.gha.restore_state(
            &st.words[0],
            &st.wide[0],
            st.counters[0],
            &coeff,
            st.mats.first(),
        );
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Quantized EASI rotation / standalone EASI stage, with the warm-up
/// gate and the reconfiguration mux (the retraction cadence lives
/// inside the kernel's own step counter).
pub struct FxpEasiStage {
    pub rot: FxpEasiRot,
    label: &'static str,
    warmup: u64,
    seen: u64,
    active: bool,
}

impl FxpEasiStage {
    pub fn new(rot: FxpEasiRot, label: &'static str, warmup: u64) -> Self {
        Self {
            rot,
            label,
            warmup,
            seen: 0,
            active: true,
        }
    }

    fn train_row(&mut self, row: &[i32]) {
        self.seen += 1;
        if self.active && self.seen > self.warmup {
            self.rot.step_raw(row);
        }
    }
}

impl Stage for FxpEasiStage {
    fn name(&self) -> &'static str {
        self.label
    }

    fn role(&self) -> StageRole {
        StageRole::Rot
    }

    fn in_dim(&self) -> usize {
        self.rot.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.rot.output_dim()
    }

    fn is_adaptive(&self) -> bool {
        true
    }

    fn bypassed(&self) -> bool {
        !self.active
    }

    fn set_active(&mut self, on: bool) {
        self.active = on;
    }

    fn advance(&mut self, rows: usize) {
        self.seen += rows as u64;
    }

    fn set_train_lanes(&mut self, lanes: usize) {
        self.rot.set_train_lanes(lanes);
    }

    fn input_spec(&self) -> Option<FxpSpec> {
        Some(self.rot.spec)
    }

    fn output_spec(&self) -> Option<FxpSpec> {
        Some(self.rot.spec)
    }

    fn step_tile_raw(&mut self, x: &[i32], rows: usize, out: Option<&mut Vec<i32>>) {
        let (m, n) = (self.rot.input_dim(), self.rot.output_dim());
        assert_eq!(x.len(), rows * m, "fxp easi stage tile shape mismatch");
        match out {
            Some(o) => {
                resize_buf(o, rows * n);
                for r in 0..rows {
                    let row = &x[r * m..(r + 1) * m];
                    self.train_row(row);
                    self.rot.transform_into(row, &mut o[r * n..(r + 1) * n]);
                }
            }
            None => {
                for r in 0..rows {
                    self.train_row(&x[r * m..(r + 1) * m]);
                }
            }
        }
    }

    fn transform_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        self.rot.transform_tile_raw(x, rows, out);
    }

    fn update_magnitude(&self) -> Option<f64> {
        Some(self.rot.update_magnitude())
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.rot.matrix())
    }

    fn save_state(&self) -> StageState {
        let (b, steps, shadow) = self.rot.save_state();
        StageState {
            mats: shadow.into_iter().collect(),
            words: vec![b],
            counters: vec![steps, self.seen],
            ..StageState::default()
        }
    }

    fn restore_state(&mut self, st: &StageState) -> anyhow::Result<()> {
        ensure!(
            st.words.len() == 1 && st.counters.len() == 2,
            "fxp easi stage state shape"
        );
        self.rot
            .restore_state(&st.words[0], st.counters[0], st.mats.first());
        self.seen = st.counters[1];
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Quantized fixed 1-D DCT truncation: a dense quantized matvec — the
/// fixed-point image of [`DctStage`] (new with the stage graph; no
/// legacy counterpart existed).
pub struct FxpDctStage {
    mat: FxpMat,
    spec: FxpSpec,
    in_dim: usize,
    out_dim: usize,
}

impl FxpDctStage {
    pub fn new(in_dim: usize, out_dim: usize, spec: FxpSpec) -> Self {
        let d = Dct1d::new(in_dim, out_dim);
        Self {
            mat: FxpMat::quantize(d.matrix(), spec),
            spec,
            in_dim,
            out_dim,
        }
    }
}

impl Stage for FxpDctStage {
    fn name(&self) -> &'static str {
        "dct"
    }

    fn role(&self) -> StageRole {
        StageRole::Rp
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn input_spec(&self) -> Option<FxpSpec> {
        Some(self.spec)
    }

    fn output_spec(&self) -> Option<FxpSpec> {
        Some(self.spec)
    }

    fn step_tile_raw(&mut self, x: &[i32], rows: usize, out: Option<&mut Vec<i32>>) {
        if let Some(o) = out {
            self.transform_tile_raw(x, rows, o);
        }
    }

    fn transform_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        let (m, n) = (self.in_dim, self.out_dim);
        assert_eq!(x.len(), rows * m, "fxp dct stage tile shape mismatch");
        resize_buf(out, rows * n);
        for r in 0..rows {
            self.mat
                .matvec_raw_into(&x[r * m..(r + 1) * m], &mut out[r * n..(r + 1) * n]);
        }
    }

    fn dense_matrix(&self) -> Option<Mat> {
        Some(self.mat.dequantize())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}
