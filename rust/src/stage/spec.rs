//! Declarative stage graphs: the `--stages` syntax, dimension/format
//! resolution, the builder that turns a [`GraphSpec`] into a
//! [`StageGraph`], and per-stage hardware pricing.
//!
//! # Stage-list syntax
//!
//! A graph is a comma-separated list of stage tokens, each
//! `name[:variant][/dim][@qI.F[:policies]]`:
//!
//! | token                         | stage                                    |
//! |-------------------------------|------------------------------------------|
//! | `rp:ternary/16`               | random projection to 16 (also `gaussian`, `achlioptas`; `rp/16` = ternary) |
//! | `whiten:gha` (or `whiten`)    | streaming GHA whitener (reduces to `/dim`, default the graph output) |
//! | `rot:easi` (or `rot`)         | square EASI rotation (the composed unit's second half) |
//! | `easi:full` / `easi:rot`      | standalone EASI trainer (Table I datapaths) |
//! | `pca` / `pca:whiten`          | batch PCA projection / whitening (f32 only) |
//! | `dct/24`                      | fixed 1-D DCT truncation                 |
//! | `identity`                    | pass-through                             |
//!
//! `@qI.F` overrides the stage's fixed-point format individually; the
//! [`PrecisionPlan`] supplies formats per [`StageRole`] otherwise, so
//! `--precision rp=q8.16,whiten=q4.12,rot=q1.15` keeps meaning what it
//! did while any cascade — `rp:ternary/16,pca`, `dct/24,whiten:gha,
//! rot:easi`, a lone `whiten:gha` — gets per-stage arithmetic with no
//! new plumbing. Unknown or duplicate stage tokens fail naming the
//! offending token.

use super::adapters::{
    DctStage, EasiStage, FxpDctStage, FxpEasiStage, FxpGhaStage, FxpRpStage, GhaStage,
    IdentityStage, PcaStage, RpStage,
};
use super::graph::{Domain, StageGraph};
use super::{Stage, StageRole};
use crate::easi::{EasiConfig, EasiMode, EasiTrainer};
use crate::fxp::{input_prescale, FxpEasiRot, FxpGha, FxpSpec, Precision};
use crate::gha::{GhaConfig, GhaWhitener};
use crate::hwmodel::ops::{dense_stage_ops, easi_ops, easi_split_ops, rp_ops};
use crate::hwmodel::{Arria10Model, NumericFormat, OpCounts, ResourceReport};
use crate::pipeline::unit::RETRACT_INTERVAL;
use crate::rp::{RandomProjection, RpDistribution};
use anyhow::{bail, ensure, Result};

/// What a declared stage computes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StageOp {
    /// Random-projection front end.
    Rp(RpDistribution),
    /// Streaming GHA whitener (the composed unit's first half).
    WhitenGha,
    /// Square EASI rotation (the composed unit's second half: warm-up
    /// gated, periodically retracted, identity-initialised).
    RotEasi,
    /// Standalone EASI trainer (the Table I datapaths; random
    /// orthonormal init, no warm-up).
    Easi(EasiMode),
    /// Batch PCA (projection or whitening) — f32 only.
    Pca { whiten: bool },
    /// Fixed 1-D DCT truncation.
    Dct,
    /// Pass-through.
    Identity,
}

/// One declared stage: the op, an optional output dimension, and an
/// optional per-stage fixed-point format override.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageDecl {
    pub op: StageOp,
    pub out_dim: Option<usize>,
    pub fxp: Option<FxpSpec>,
}

impl StageDecl {
    pub fn new(op: StageOp) -> Self {
        Self {
            op,
            out_dim: None,
            fxp: None,
        }
    }

    pub fn with_dim(mut self, dim: usize) -> Self {
        self.out_dim = Some(dim);
        self
    }

    /// Canonical token (round-trips through [`parse_stage_list`]).
    pub fn label(&self) -> String {
        let base = match self.op {
            StageOp::Rp(RpDistribution::Ternary) => "rp:ternary".to_string(),
            StageOp::Rp(RpDistribution::Achlioptas) => "rp:achlioptas".to_string(),
            StageOp::Rp(RpDistribution::Gaussian) => "rp:gaussian".to_string(),
            StageOp::WhitenGha => "whiten:gha".to_string(),
            StageOp::RotEasi => "rot:easi".to_string(),
            StageOp::Easi(EasiMode::Full) => "easi:full".to_string(),
            StageOp::Easi(EasiMode::RotationOnly) => "easi:rot".to_string(),
            StageOp::Easi(_) => "easi".to_string(),
            StageOp::Pca { whiten: false } => "pca".to_string(),
            StageOp::Pca { whiten: true } => "pca:whiten".to_string(),
            StageOp::Dct => "dct".to_string(),
            StageOp::Identity => "identity".to_string(),
        };
        let mut s = base;
        if let Some(d) = self.out_dim {
            s.push_str(&format!("/{d}"));
        }
        if let Some(f) = self.fxp {
            s.push_str(&format!("@{}", f.label()));
        }
        s
    }
}

/// Parse a comma-separated stage list. Unknown stage names/variants and
/// duplicate adaptive/front-end stages fail with an error naming the
/// offending token.
pub fn parse_stage_list(s: &str) -> Result<Vec<StageDecl>> {
    let mut out: Vec<StageDecl> = Vec::new();
    for token in s.split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        let t = token.to_ascii_lowercase();
        let (head, fmt) = match t.split_once('@') {
            Some((h, f)) => (h, Some(FxpSpec::parse(f)?)),
            None => (t.as_str(), None),
        };
        let (kind, dim) = match head.split_once('/') {
            Some((k, d)) => {
                let dim: usize = d.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "bad dimension in stage '{token}' (expected e.g. rp:ternary/16)"
                    )
                })?;
                (k, Some(dim))
            }
            None => (head, None),
        };
        let (name, variant) = match kind.split_once(':') {
            Some((n, v)) => (n, Some(v)),
            None => (kind, None),
        };
        let op = match (name, variant) {
            ("rp", None | Some("ternary")) => StageOp::Rp(RpDistribution::Ternary),
            ("rp", Some("achlioptas")) => StageOp::Rp(RpDistribution::Achlioptas),
            ("rp", Some("gaussian")) => StageOp::Rp(RpDistribution::Gaussian),
            ("whiten", None | Some("gha")) => StageOp::WhitenGha,
            ("rot", None | Some("easi")) => StageOp::RotEasi,
            ("easi", None | Some("full")) => StageOp::Easi(EasiMode::Full),
            ("easi", Some("rot" | "rotation")) => StageOp::Easi(EasiMode::RotationOnly),
            ("pca", None) => StageOp::Pca { whiten: false },
            ("pca", Some("whiten")) => StageOp::Pca { whiten: true },
            ("dct", None) => StageOp::Dct,
            ("identity", None) => StageOp::Identity,
            _ => bail!(
                "unknown stage '{token}' in stage list (rp[:ternary|achlioptas|gaussian]/D, \
                 whiten:gha, rot:easi, easi[:full|rot], pca[:whiten], dct, identity)"
            ),
        };
        // Duplicate front-end / adaptive stages are almost certainly a
        // typo'd list; fail naming the token rather than building a
        // silently-weird cascade.
        let duplicate = out.iter().any(|d| match (d.op, op) {
            (StageOp::Rp(_), StageOp::Rp(_)) => true,
            (StageOp::WhitenGha, StageOp::WhitenGha) => true,
            (StageOp::RotEasi | StageOp::Easi(_), StageOp::RotEasi | StageOp::Easi(_)) => true,
            _ => false,
        });
        if duplicate {
            bail!("duplicate stage '{token}' in stage list");
        }
        out.push(StageDecl {
            op,
            out_dim: dim,
            fxp: fmt,
        });
    }
    ensure!(!out.is_empty(), "stage list '{s}' names no stages");
    Ok(out)
}

/// A declared DR graph: stage list + dimensions + arithmetic + the
/// hyper-parameters the adaptive stages consume. The single source both
/// `DrPipeline` (legacy `StageSpec` forms map onto it) and the
/// coordinator build from.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub input_dim: usize,
    pub output_dim: usize,
    pub stages: Vec<StageDecl>,
    pub seed: u64,
    pub precision: Precision,
    /// GHA (whitening) learning rate.
    pub mu_w: f32,
    /// EASI learning rate (rotation and standalone stages).
    pub mu_rot: f32,
    /// Whiten-only warm-up before the unit rotation trains; `None`
    /// derives the legacy `(rows/2).min(2000)` from the fit data.
    pub rot_warmup: Option<u64>,
    /// Streaming passes over the training set.
    pub epochs: usize,
}

/// One stage after dimension/role resolution.
#[derive(Debug, Clone, Copy)]
pub struct ResolvedStage {
    pub op: StageOp,
    pub in_dim: usize,
    pub out_dim: usize,
    pub role: StageRole,
    pub fxp_override: Option<FxpSpec>,
}

fn role_of(op: StageOp) -> StageRole {
    match op {
        StageOp::Rp(_) | StageOp::Dct | StageOp::Identity => StageRole::Rp,
        StageOp::WhitenGha | StageOp::Pca { .. } => StageRole::Whiten,
        StageOp::RotEasi | StageOp::Easi(_) => StageRole::Rot,
    }
}

fn is_adaptive_op(op: StageOp) -> bool {
    matches!(op, StageOp::WhitenGha | StageOp::RotEasi | StageOp::Easi(_))
}

impl GraphSpec {
    /// Canonical stage-list label (round-trips through
    /// [`parse_stage_list`]).
    pub fn stages_label(&self) -> String {
        self.stages
            .iter()
            .map(StageDecl::label)
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Resolve per-stage dimensions and roles. Unset dims default to
    /// the graph output (square stages keep their input); every chain
    /// inconsistency fails with a message naming the stage.
    pub fn resolve(&self) -> Result<Vec<ResolvedStage>> {
        ensure!(!self.stages.is_empty(), "stage list is empty");
        ensure!(
            self.output_dim >= 1 && self.output_dim <= self.input_dim,
            "need 1 <= output_dim <= input_dim"
        );
        let mut out = Vec::with_capacity(self.stages.len());
        let mut dim = self.input_dim;
        let mut seen_adaptive = false;
        for d in &self.stages {
            let label = d.label();
            // A per-stage format override on an f32 graph would be
            // silently dead — fail loudly naming the token.
            ensure!(
                d.fxp.is_none() || self.precision.is_fixed(),
                "stage '{label}' has a fixed-point format override, but the \
                 graph precision is f32 (pass --precision qI.F or a plan)"
            );
            let out_dim = match (d.op, d.out_dim) {
                (StageOp::Rp(_), None) => {
                    bail!("stage '{label}' needs an explicit dimension (e.g. rp:ternary/16)")
                }
                (StageOp::RotEasi, Some(k)) if k != dim => {
                    bail!("stage '{label}' is square: /{k} conflicts with its input dim {dim}")
                }
                (StageOp::RotEasi, _) => dim,
                (StageOp::Identity, Some(k)) if k != dim => {
                    bail!("stage '{label}' cannot change dimensionality ({dim} -> {k})")
                }
                (StageOp::Identity, _) => dim,
                (_, Some(k)) => k,
                (_, None) => self.output_dim,
            };
            ensure!(
                out_dim >= 1 && out_dim <= dim,
                "stage '{label}' must reduce: need 1 <= {out_dim} <= {dim}"
            );
            if matches!(d.op, StageOp::Pca { .. }) {
                ensure!(
                    !seen_adaptive,
                    "batch stage '{label}' cannot follow an adaptive stage"
                );
            }
            seen_adaptive = seen_adaptive || is_adaptive_op(d.op);
            out.push(ResolvedStage {
                op: d.op,
                in_dim: dim,
                out_dim,
                role: role_of(d.op),
                fxp_override: d.fxp,
            });
            dim = out_dim;
        }
        ensure!(
            dim == self.output_dim,
            "stage list ends at dim {dim}, but output_dim is {}",
            self.output_dim
        );
        Ok(out)
    }

    /// Build the graph. `fit_rows` (when known) feeds the legacy
    /// auto warm-up `(rows/2).min(2000)` when [`GraphSpec::rot_warmup`]
    /// is `None`.
    pub fn build(&self, fit_rows: Option<usize>) -> Result<StageGraph> {
        let resolved = self.resolve()?;
        let warmup = self
            .rot_warmup
            .unwrap_or_else(|| fit_rows.map_or(2000, |r| ((r / 2).min(2000)) as u64));
        match self.precision {
            Precision::F32 => self.build_f32(&resolved, warmup),
            Precision::Fixed(_) => self.build_fxp(&resolved, warmup),
        }
    }

    fn build_rp(
        &self,
        resolved: &[ResolvedStage],
        i: usize,
        dist: RpDistribution,
    ) -> RandomProjection {
        let rs = &resolved[i];
        let rp = RandomProjection::new(rs.in_dim, rs.out_dim, dist, self.seed);
        // Single source of the unit-variance policy: adaptive stages
        // assume unit-variance inputs, fixed stages get the raw
        // distance-preserving projection (same rule the legacy
        // front-end builder applied).
        if resolved[i + 1..].iter().any(|r| is_adaptive_op(r.op)) {
            rp.unit_variance()
        } else {
            rp
        }
    }

    fn build_f32(&self, resolved: &[ResolvedStage], warmup: u64) -> Result<StageGraph> {
        let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(resolved.len());
        for (i, rs) in resolved.iter().enumerate() {
            let stage: Box<dyn Stage> = match rs.op {
                StageOp::Rp(dist) => Box::new(RpStage::new(self.build_rp(resolved, i, dist))),
                StageOp::WhitenGha => Box::new(GhaStage::new(GhaWhitener::new(GhaConfig {
                    input_dim: rs.in_dim,
                    output_dim: rs.out_dim,
                    mu: self.mu_w,
                    seed: self.seed,
                    ..Default::default()
                }))),
                StageOp::RotEasi => {
                    let n = rs.out_dim;
                    let t = EasiTrainer::new(EasiConfig {
                        input_dim: n,
                        output_dim: n,
                        mu: self.mu_rot,
                        mode: EasiMode::RotationOnly,
                        normalized: true,
                        max_norm: 4.0 * (n as f32).sqrt(),
                        clip: 0.05,
                        random_init: None,
                    });
                    Box::new(EasiStage::new(t, "rot:easi", warmup, Some(RETRACT_INTERVAL)))
                }
                StageOp::Easi(mode) => {
                    let t = EasiTrainer::new(EasiConfig {
                        input_dim: rs.in_dim,
                        output_dim: rs.out_dim,
                        mu: self.mu_rot,
                        mode,
                        normalized: true,
                        max_norm: if mode == EasiMode::RotationOnly {
                            4.0 * (rs.out_dim as f32).sqrt()
                        } else {
                            1e4
                        },
                        clip: 0.05,
                        random_init: Some(self.seed),
                    });
                    Box::new(EasiStage::new(t, "easi", 0, None))
                }
                StageOp::Pca { whiten } => Box::new(PcaStage::new(rs.in_dim, rs.out_dim, whiten)),
                StageOp::Dct => Box::new(DctStage::new(rs.in_dim, rs.out_dim)),
                StageOp::Identity => Box::new(IdentityStage::new(rs.in_dim, None)),
            };
            stages.push(stage);
        }
        Ok(StageGraph::new(
            stages,
            Domain::F32,
            self.input_dim,
            self.output_dim,
        ))
    }

    /// Per-stage fixed-point formats: each stage's `@override` first,
    /// then the plan's format for the stage's role (identity inherits
    /// its predecessor's boundary).
    fn fxp_specs(&self, resolved: &[ResolvedStage]) -> Vec<FxpSpec> {
        let plan = self.precision.plan().expect("fixed-point graph");
        let mut specs = Vec::with_capacity(resolved.len());
        let mut prev: Option<FxpSpec> = None;
        for rs in resolved {
            let sp = match rs.fxp_override {
                Some(sp) => sp,
                None => match rs.op {
                    StageOp::Identity => prev.unwrap_or_else(|| plan.spec_for(rs.role)),
                    _ => plan.spec_for(rs.role),
                },
            };
            specs.push(sp);
            prev = Some(sp);
        }
        specs
    }

    /// The entry prescale of a fixed-point graph: the most conservative
    /// of the formats a raw sample flows through before the first
    /// whitener renormalises (the legacy `entry_prescale` rule,
    /// generalised to any cascade).
    fn fxp_prescale(resolved: &[ResolvedStage], specs: &[FxpSpec]) -> f32 {
        let mut ps = 1.0f32;
        for (rs, sp) in resolved.iter().zip(specs) {
            ps = ps.min(input_prescale(sp));
            if rs.op == StageOp::WhitenGha {
                break;
            }
        }
        ps
    }

    fn build_fxp(&self, resolved: &[ResolvedStage], warmup: u64) -> Result<StageGraph> {
        let plan = self.precision.plan().expect("fixed-point graph");
        let specs = self.fxp_specs(resolved);
        let prescale = Self::fxp_prescale(resolved, &specs);
        let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(resolved.len());
        // σ of the most recent whitener: downstream rotation learning
        // rates fold in σ⁻⁴ (host-side constant folding, exact — σ is a
        // power of two); rotations with no whitener upstream compensate
        // the entry prescale instead, as the legacy fixed path did.
        let mut last_sigma: Option<f32> = None;
        for (i, rs) in resolved.iter().enumerate() {
            let spec = specs[i];
            let stage: Box<dyn Stage> = match rs.op {
                StageOp::Rp(dist) => {
                    Box::new(FxpRpStage::new(self.build_rp(resolved, i, dist), spec))
                }
                StageOp::WhitenGha => {
                    let mut gha = FxpGha::new(
                        rs.in_dim,
                        rs.out_dim,
                        self.mu_w,
                        5e-3,
                        self.seed,
                        spec,
                        plan.quant,
                    );
                    // The σ target must satisfy the *narrower* of this
                    // stage's format and any downstream rotation's —
                    // ±4σ has to fit both sides of the boundary.
                    let rot_int = resolved[i + 1..]
                        .iter()
                        .zip(&specs[i + 1..])
                        .find(|(r, _)| r.role == StageRole::Rot)
                        .map(|(_, sp)| sp.format.int_bits);
                    let narrow = match rot_int {
                        Some(r) => spec.format.int_bits.min(r),
                        None => spec.format.int_bits,
                    };
                    gha.set_sigma_shift((3 - narrow as i32).max(0));
                    last_sigma = Some(gha.target_sigma());
                    Box::new(FxpGhaStage::new(gha))
                }
                StageOp::RotEasi => {
                    let mu_eff = match last_sigma {
                        Some(sigma) => self.mu_rot / (sigma * sigma * sigma * sigma),
                        None => self.mu_rot / prescale.powi(4),
                    };
                    let rot = FxpEasiRot::new(
                        rs.out_dim,
                        rs.out_dim,
                        mu_eff,
                        None,
                        spec,
                        plan.quant,
                    );
                    Box::new(FxpEasiStage::new(rot, "rot:easi", warmup))
                }
                StageOp::Easi(mode) => {
                    if mode != EasiMode::RotationOnly {
                        bail!(
                            "fixed-point EASI implements the paper's rotation-only \
                             datapath; got {mode:?}"
                        );
                    }
                    // Update terms scale as the fourth power of the
                    // input scale: σ behind a whitener, the entry
                    // prescale otherwise — fold the compensation into μ
                    // (exact power of two).
                    let mu_eff = match last_sigma {
                        Some(sigma) => self.mu_rot / (sigma * sigma * sigma * sigma),
                        None => self.mu_rot / prescale.powi(4),
                    };
                    let rot = FxpEasiRot::new(
                        rs.in_dim,
                        rs.out_dim,
                        mu_eff,
                        Some(self.seed),
                        spec,
                        plan.quant,
                    );
                    Box::new(FxpEasiStage::new(rot, "easi", 0))
                }
                StageOp::Dct => Box::new(FxpDctStage::new(rs.in_dim, rs.out_dim, spec)),
                StageOp::Identity => Box::new(IdentityStage::new(rs.in_dim, Some(spec))),
                StageOp::Pca { .. } => bail!(
                    "fixed-point precision supports the streaming stages \
                     (easi rotation-only, ica, identity), not {:?}",
                    rs.op
                ),
            };
            stages.push(stage);
        }
        let entry = specs[0];
        Ok(StageGraph::new(
            stages,
            Domain::Fxp { entry, prescale },
            self.input_dim,
            self.output_dim,
        ))
    }

    // ----------------------------------------------------- hw pricing

    /// The legacy `(m, p, n)` shape, when this graph is one of the
    /// forms `cost_precision` has always priced — pricing those through
    /// the same path keeps every historical number bit-for-bit.
    fn legacy_hw_shape(&self) -> Option<(usize, Option<usize>, usize)> {
        if self.stages.iter().any(|d| d.fxp.is_some()) {
            return None;
        }
        let ops: Vec<StageOp> = self.stages.iter().map(|d| d.op).collect();
        let (p, rest): (Option<usize>, &[StageOp]) = match ops.as_slice() {
            [StageOp::Rp(_), rest @ ..] => (self.stages[0].out_dim, rest),
            rest => (None, rest),
        };
        match rest {
            [StageOp::WhitenGha, StageOp::RotEasi] | [StageOp::Easi(_)] => {
                Some((self.input_dim, p, self.output_dim))
            }
            _ => None,
        }
    }

    /// Per-stage operator inventories and operand formats — the
    /// fold-ready view of the graph for [`Arria10Model::cost_stages`].
    pub fn hw_ops(&self) -> Result<Vec<(String, OpCounts, NumericFormat)>> {
        let resolved = self.resolve()?;
        let fmt_of = |spec: Option<FxpSpec>| match (&self.precision, spec) {
            (Precision::F32, _) => NumericFormat::Fp32,
            (Precision::Fixed(_), Some(sp)) => NumericFormat::Fixed {
                width_bits: sp.format.width(),
            },
            (Precision::Fixed(plan), None) => NumericFormat::Fixed {
                width_bits: plan.widest_width(),
            },
        };
        let specs: Option<Vec<FxpSpec>> = self
            .precision
            .plan()
            .map(|_| self.fxp_specs(&resolved));
        let mut out = Vec::with_capacity(resolved.len());
        let mut last_whiten_in: Option<usize> = None;
        for (i, rs) in resolved.iter().enumerate() {
            let spec = specs.as_ref().map(|s| s[i]);
            let ops = match rs.op {
                StageOp::Rp(_) => rp_ops(rs.in_dim, rs.out_dim),
                StageOp::WhitenGha => {
                    last_whiten_in = Some(rs.in_dim);
                    easi_split_ops(rs.in_dim, rs.out_dim).0
                }
                StageOp::RotEasi => match last_whiten_in {
                    // The rotation share of the split depends on the
                    // whitener's input width (stage 4's F·B is the
                    // O(m·n²) hot spot).
                    Some(m) => easi_split_ops(m, rs.out_dim).1,
                    None => easi_ops(rs.in_dim, rs.out_dim),
                },
                StageOp::Easi(_) => easi_ops(rs.in_dim, rs.out_dim),
                StageOp::Pca { .. } | StageOp::Dct => dense_stage_ops(rs.in_dim, rs.out_dim),
                StageOp::Identity => OpCounts::default(),
            };
            out.push((
                self.stages[i].label(),
                ops,
                fmt_of(spec),
            ));
        }
        Ok(out)
    }

    /// Price the graph: legacy shapes delegate to `cost_precision`
    /// (bit-identical to every historical sweep number), anything else
    /// folds the per-stage inventories at their per-stage widths.
    pub fn hw_cost(&self, model: &Arria10Model) -> Result<ResourceReport> {
        if let Some((m, p, n)) = self.legacy_hw_shape() {
            return Ok(model.cost_precision(m, p, n, &self.precision));
        }
        let parts = self.hw_ops()?;
        let stages: Vec<(OpCounts, NumericFormat)> =
            parts.into_iter().map(|(_, ops, fmt)| (ops, fmt)).collect();
        Ok(model.cost_stages(&stages))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(stages: &str, m: usize, n: usize, precision: &str) -> GraphSpec {
        GraphSpec {
            input_dim: m,
            output_dim: n,
            stages: parse_stage_list(stages).unwrap(),
            seed: 7,
            precision: Precision::parse(precision).unwrap(),
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rot_warmup: Some(100),
            epochs: 1,
        }
    }

    #[test]
    fn parse_known_stage_tokens() {
        let decls = parse_stage_list("rp:ternary/16,whiten:gha,rot:easi").unwrap();
        assert_eq!(decls.len(), 3);
        assert_eq!(decls[0].op, StageOp::Rp(RpDistribution::Ternary));
        assert_eq!(decls[0].out_dim, Some(16));
        assert_eq!(decls[1].op, StageOp::WhitenGha);
        assert_eq!(decls[2].op, StageOp::RotEasi);
        // Aliases and defaults.
        let decls = parse_stage_list("rp/8,whiten,rot").unwrap();
        assert_eq!(decls[0].op, StageOp::Rp(RpDistribution::Ternary));
        assert_eq!(decls[1].op, StageOp::WhitenGha);
        assert_eq!(decls[2].op, StageOp::RotEasi);
        // Per-stage format overrides parse and round-trip.
        let decls = parse_stage_list("rp:ternary/16@q8.16,whiten:gha@q4.12:trunc").unwrap();
        assert_eq!(decls[0].fxp, Some(FxpSpec::parse("q8.16").unwrap()));
        assert_eq!(decls[1].fxp, Some(FxpSpec::parse("q4.12:trunc").unwrap()));
        for d in &decls {
            let back = parse_stage_list(&d.label()).unwrap();
            assert_eq!(back[0], *d, "label {} must round-trip", d.label());
        }
    }

    #[test]
    fn parse_rejects_unknown_tokens_naming_them() {
        for (list, needle) in [
            ("rp:ternary/16,frobnicate", "frobnicate"),
            ("whiten:svd", "whiten:svd"),
            ("rp:binary/16", "rp:binary/16"),
            ("pca:kernel", "pca:kernel"),
            ("identity:twice", "identity:twice"),
        ] {
            let err = parse_stage_list(list).unwrap_err().to_string();
            assert!(
                err.contains("unknown stage") && err.contains(needle),
                "{list}: {err}"
            );
        }
        // Bad dimension token.
        let err = parse_stage_list("rp:ternary/lots").unwrap_err().to_string();
        assert!(err.contains("bad dimension"), "{err}");
    }

    #[test]
    fn parse_rejects_duplicate_stages_naming_them() {
        for (list, needle) in [
            ("whiten:gha,whiten:gha", "whiten:gha"),
            ("rot:easi,rot:easi", "rot:easi"),
            ("rp:ternary/16,rp:gaussian/8", "rp:gaussian/8"),
            ("rot:easi,easi:full", "easi:full"),
        ] {
            let err = parse_stage_list(list).unwrap_err().to_string();
            assert!(
                err.contains("duplicate stage") && err.contains(needle),
                "{list}: {err}"
            );
        }
    }

    #[test]
    fn resolve_dims_and_errors() {
        let g = spec("rp:ternary/16,whiten:gha,rot:easi", 32, 8, "f32");
        let r = g.resolve().unwrap();
        assert_eq!(r[0].in_dim, 32);
        assert_eq!(r[0].out_dim, 16);
        assert_eq!(r[1].out_dim, 8);
        assert_eq!(r[2].in_dim, 8);
        assert_eq!(r[2].out_dim, 8);
        // RP without a dimension.
        let g = spec("rp:ternary,whiten:gha", 32, 8, "f32");
        assert!(g.resolve().unwrap_err().to_string().contains("explicit dimension"));
        // Chain must land on output_dim.
        let g = spec("dct/16", 32, 8, "f32");
        assert!(g.resolve().is_err());
        // Batch stage behind an adaptive stage is rejected.
        let g = spec("whiten:gha/16,pca", 32, 8, "f32");
        let err = g.resolve().unwrap_err().to_string();
        assert!(err.contains("cannot follow an adaptive stage"), "{err}");
        // A per-stage format override on an f32 graph is dead — reject
        // loudly naming the stage.
        let g = spec("rp:ternary/16@q8.16,whiten:gha,rot:easi", 32, 8, "f32");
        let err = g.resolve().unwrap_err().to_string();
        assert!(
            err.contains("rp:ternary/16@q8.16") && err.contains("f32"),
            "{err}"
        );
    }

    #[test]
    fn legacy_shapes_price_identically_to_cost_precision() {
        let model = Arria10Model::paper_calibrated();
        for (stages, m, p, n) in [
            ("rp:ternary/16,whiten:gha,rot:easi", 32usize, Some(16usize), 8usize),
            ("whiten:gha,rot:easi", 32, None, 8),
            ("easi:full/8", 32, None, 8),
            ("rp:ternary/16,easi:rot", 32, Some(16), 8),
        ] {
            for prec in ["f32", "q4.12", "rp=q8.16,whiten=q4.12,rot=q1.15"] {
                let g = spec(stages, m, n, prec);
                let got = g.hw_cost(&model).unwrap();
                let want =
                    model.cost_precision(m, p, n, &Precision::parse(prec).unwrap());
                assert_eq!(got.dsps, want.dsps, "{stages} {prec} DSPs");
                assert_eq!(got.alms, want.alms, "{stages} {prec} ALMs");
                assert_eq!(got.register_bits, want.register_bits, "{stages} {prec} regs");
            }
        }
    }

    #[test]
    fn graph_fold_prices_new_scenarios() {
        let model = Arria10Model::paper_calibrated();
        // rp → pca (f32): RP soft add/subs + a dense matvec.
        let g = spec("rp:ternary/16,pca", 32, 8, "f32");
        let c = g.hw_cost(&model).unwrap();
        assert!(c.alms > 0 && c.dsps > 0);
        // dct → whiten → rot: fold of three inventories.
        let g = spec("dct/16,whiten:gha,rot:easi", 32, 8, "f32");
        let c32 = g.hw_cost(&model).unwrap();
        let gq = spec("dct/16,whiten:gha,rot:easi", 32, 8, "q4.12");
        let cq = gq.hw_cost(&model).unwrap();
        assert!(cq.dsps < c32.dsps, "fixed point must undercut f32");
        assert!(cq.alms < c32.alms);
        // whiten-only fixed point: just the whiten share.
        let g = spec("whiten:gha", 32, 8, "q4.12");
        let c = g.hw_cost(&model).unwrap();
        let full = spec("whiten:gha,rot:easi", 32, 8, "q4.12")
            .hw_cost(&model)
            .unwrap();
        assert!(c.dsps < full.dsps, "whiten share must undercut whiten+rot");
        // A per-stage @override changes the fold (wider RP → more ALMs).
        let narrow = spec("rp:ternary/16@q4.12,whiten:gha,rot:easi", 32, 8, "q4.12");
        let wide = spec("rp:ternary/16@q8.16,whiten:gha,rot:easi", 32, 8, "q4.12");
        let cn = narrow.hw_cost(&model).unwrap();
        let cw = wide.hw_cost(&model).unwrap();
        assert!(cw.alms > cn.alms, "wider RP accumulator must cost more ALMs");
    }

    #[test]
    fn builds_f32_and_fxp_graphs() {
        use crate::linalg::Mat;
        let x = Mat::from_fn(200, 32, |i, j| ((i * 7 + j * 3) % 13) as f32 / 13.0 - 0.5);
        for prec in ["f32", "q4.12"] {
            let g = spec("rp:ternary/16,whiten:gha,rot:easi", 32, 8, prec);
            let mut graph = g.build(Some(x.rows_count())).unwrap();
            graph.fit(&x, 1);
            let y = graph.transform_rows(&x);
            assert_eq!(y.shape(), (200, 8));
            assert!(y.as_slice().iter().all(|v| v.is_finite()));
        }
        // Batch stages reject fixed point with the legacy message.
        let g = spec("pca", 32, 8, "q4.12");
        let err = g.build(None).unwrap_err().to_string();
        assert!(
            err.contains("fixed-point precision supports the streaming stages"),
            "{err}"
        );
    }
}
