//! # dimred — hardware-friendly dimensionality reduction
//!
//! Reproduction of Nazemi, Eshratifar & Pedram, *"A Hardware-Friendly
//! Algorithm for Scalable Training and Deployment of Dimensionality
//! Reduction Models on FPGA"* (2018), as a three-layer Rust + JAX +
//! Pallas stack (see `DESIGN.md`).
//!
//! The crate is organised bottom-up:
//!
//! * Substrates: [`rng`], [`linalg`], [`fxp`] (bit-accurate fixed-point
//!   arithmetic + quantized kernels), [`datasets`]
//! * Dimensionality-reduction algorithms: [`rp`] (random projection),
//!   [`easi`] (EASI / ICA, including the paper's modified rotation-only
//!   datapath), [`gha`] (Sanger whitening), [`pca`] (adaptive
//!   whitening, batch PCA, bilinear/DCT)
//! * Downstream model: [`mlp`] (2×64 ReLU classifier)
//! * Hardware co-design: [`hwmodel`] (bitwidth-aware Arria-10 resource
//!   + pipeline model, regenerates the paper's Table II)
//! * System: [`runtime`] (PJRT artifact loader), [`coordinator`]
//!   (streaming training service; per-stream [`coordinator::Session`]s
//!   with checkpoint-based evict/restore), [`serve`] (multi-tenant
//!   serving layer: tenant registry, shard scheduler, synthetic
//!   workloads behind `dimred serve`), [`stage`] (the unified
//!   stage-graph datapath: one `Stage` abstraction over f32 and fixed
//!   point), [`pipeline`] (composed DR pipelines — thin façade over the
//!   stage graph, f32 or fixed-point via [`fxp::Precision`]),
//!   [`telemetry`] (per-stage counters, fxp saturation health, run
//!   metrics and the `dimred report` profiling surface), [`config`]

pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod easi;
pub mod experiments;
pub mod fxp;
pub mod gha;
pub mod hwmodel;
pub mod linalg;
pub mod mlp;
pub mod pca;
pub mod pipeline;
pub mod rng;
pub mod rp;
pub mod runtime;
pub mod serve;
pub mod stage;
pub mod telemetry;
pub mod util;

/// Crate-wide result alias (anyhow-based, matches the binary's error style).
pub type Result<T> = anyhow::Result<T>;
