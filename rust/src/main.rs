//! `dimred` — CLI for the hardware-friendly dimensionality-reduction
//! training service (Nazemi et al. 2018 reproduction).
//!
//! Subcommands:
//!   train        stream-train a DR pipeline (+ downstream classifier)
//!   table1       regenerate the paper's Table I (accuracy)
//!   table2       regenerate the paper's Table II (FPGA cost model),
//!                plus bitwidth-aware fixed-point pricing
//!   fig1 <set>   regenerate a Fig. 1 accuracy-vs-dimensions series
//!   fxp-sweep    accuracy-vs-bitwidth sweep (quantized pipelines)
//!   pareto       accuracy/cost Pareto frontier over precision plans
//!                (mixed precision × bit-exact/STE training)
//!   report       profile a training run: per-stage time share,
//!                saturation rate, raw-word occupancy, headroom
//!   serve        multi-tenant serving layer: N training sessions
//!                sharded across worker threads, per-tenant telemetry
//!   artifacts    list the AOT artifacts the runtime can execute
//!   timing       pipeline timing model (frequency / latency)
//!
//! Examples:
//!   dimred train --dataset waveform --mode rp-easi --backend pjrt \
//!       --intermediate-dim 16 --output-dim 8
//!   dimred train --mode rp-easi --precision q4.12
//!   dimred train --stages rp:ternary/16,whiten:gha,rot:easi
//!   dimred train --stages rp:ternary/16,pca --no-classifier
//!   dimred train --stages dct/16,whiten:gha,rot:easi --precision q4.12
//!   dimred train --stages whiten:gha --precision q4.12
//!   dimred train --precision rp=q8.16,whiten=q4.12,rot=q1.15,qat=ste
//!   dimred train --precision q1.15:wrap:trunc
//!   dimred table2 --precision q1.15
//!   dimred fig1 mnist --points 4
//!   dimred fxp-sweep waveform --json sweep.json
//!   dimred fxp-sweep waveform --stages whiten:gha
//!   dimred pareto waveform --json pareto.json
//!   dimred train --precision q4.12 --telemetry
//!   dimred report --precision q4.12 --epochs 1 --json TELEMETRY_snapshot.json
//!   dimred serve --tenants 16 --shards 4 --arrival skewed:10
//!   dimred serve --smoke --json SERVE_report.json
//!   dimred serve --smoke --inject-faults "t1:nan,t3:ingest@0.5"

use anyhow::{bail, Context, Result};
use dimred::config::{Backend, ExperimentConfig};
use dimred::coordinator::TrainingService;
use dimred::datasets::{
    ads_like::AdsLikeConfig, har_like::HarLikeConfig, mnist_like::MnistLikeConfig,
    waveform::WaveformConfig, Dataset,
};
use dimred::fxp::Precision;
use dimred::hwmodel::{
    paper_table_ii_configs, table_ii, HwConfig, NumericFormat, PipelineModel, PAPER_TABLE_II,
};
use dimred::runtime::Runtime;
use dimred::util::cli::Args;
use std::path::Path;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const FLAGS: &[&str] = &[
    "no-classifier",
    "help",
    "verbose",
    "smoke",
    "telemetry",
    "evict-idle",
    "no-validate-ingest",
    "pipeline",
    "no-pipeline",
];

fn run() -> Result<()> {
    let args = Args::from_env(FLAGS)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "table1" => cmd_table1(&args),
        "table2" => cmd_table2(&args),
        "fig1" => cmd_fig1(&args),
        "fxp-sweep" => cmd_fxp_sweep(&args),
        "pareto" => cmd_pareto(&args),
        "bench" => cmd_bench(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => cmd_artifacts(&args),
        "timing" => cmd_timing(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `dimred help`)"),
    }
}

const HELP: &str = "\
dimred — hardware-friendly dimensionality reduction (paper reproduction)

USAGE: dimred <command> [options]

COMMANDS:
  train       stream-train a DR pipeline, then train + evaluate the
              2x64 classifier on the reduced features
  table1      regenerate Table I (waveform accuracy, 4 configurations)
  table2      regenerate Table II (Arria-10 resource model; add
              --precision qI.F for fixed-point pricing, or omit for the
              fp32-vs-fixed comparison)
  fig1 <ds>   regenerate Fig. 1 (accuracy vs output dims; ds = mnist|har|ads)
  fxp-sweep <ds>  accuracy-vs-bitwidth sweep (ds = waveform|har);
              --formats q4.4,q4.8,... --epochs E --json FILE
  pareto <ds> accuracy/cost Pareto frontier over precision plans
              (ds = waveform|har); --plans \"PLAN;PLAN;...\" --epochs E
              --seed S --json FILE. Plans are precision strings
              (`;`-separated — the plan syntax itself uses commas);
              default grid mixes uniform/mixed and bit-exact/STE.
  bench       datapath throughput: f32 vs fixed point, per-sample vs
              tiled vs multi-lane, train + forward paths, plus the
              multi-tenant serving family (aggregate samples/s of 8
              sessions on 2/4 shards vs the single-session baseline).
              Proves bit-identity before timing, writes the
              golden-schema'd BENCH_throughput.json. Options:
              --datasets waveform,har --tile T (default 256)
              --lanes L (default 4) --seed S --json FILE (default
              BENCH_throughput.json) --smoke (tiny CI sizes, same
              schema)
  report      profile a training run with telemetry forced on: per-stage
              time share, samples/s, saturation rate, raw-word occupancy
              histogram and a headroom recommendation per stage. Takes
              the train options (classifier off by default); --json FILE
              also writes the schema-validated telemetry snapshot
  serve       run a synthetic multi-tenant workload through the serving
              layer: one training session per tenant, sharded across
              worker threads with per-tenant bounded queues, round-robin
              quanta and shape-coalesced scheduling (see SERVE OPTIONS)
  artifacts   list AOT executables from the manifest
  timing      clock/latency model for EASI vs RP+EASI

TRAIN OPTIONS:
  --dataset waveform|mnist|har|ads   (default waveform)
  --mode easi|pca-whiten|rp|rp-easi  (default rp-easi)
  --stages LIST                      (explicit stage graph replacing the
                                      mode mapping; comma-separated
                                      name[:variant][/dim][@qI.F] tokens:
                                      rp:ternary|achlioptas|gaussian/D,
                                      whiten:gha, rot:easi, easi:full|rot,
                                      pca[:whiten], dct, identity. E.g.
                                      rp:ternary/16,whiten:gha,rot:easi
                                      (the paper), rp:ternary/16,pca,
                                      dct/16,whiten:gha,rot:easi, or a
                                      lone whiten:gha. Native backend
                                      only; fxp-sweep/pareto take the
                                      same flag)
  --backend native|pjrt              (default native)
  --precision f32|qI.F|PLAN          (default f32. qI.F takes optional
                                      policy suffixes :wrap / :trunc
                                      (default saturate+nearest), e.g.
                                      q1.15:wrap:trunc. PLAN is
                                      per-stage mixed precision + QAT:
                                      rp=q8.16,whiten=q4.12,rot=q1.15
                                      [,qat=ste]. Fixed point runs the
                                      bit-accurate datapath, native
                                      backend only)
  --input-dim M --intermediate-dim P --output-dim N
  --mu F --epochs E --batch B --seed S --queue-depth Q
  --lanes L                          (forward-path lanes for fixed-point
                                      bulk transforms; bit-identical
                                      merge, default 1. The f32 engine
                                      transforms via one dense matmul
                                      and ignores this)
  --train-lanes L                    (training-path lanes for fixed
                                      point: shards the entry quantizer
                                      and the EASI STE shadow backward
                                      pass, bit-identical to sequential;
                                      order-dependent recursions stay
                                      sequential. Default 1, never
                                      spawns)
  --artifacts DIR                    (default artifacts/)
  --config FILE.json                 (load config, flags override)
  --no-classifier                    (skip the MLP stage)
  --telemetry                        (instrument the datapath: per-stage
                                      counters + fxp saturation health,
                                      periodic JSONL progress events, and
                                      a schema-validated snapshot written
                                      at the end of the run)
  --telemetry-out FILE               (snapshot path, implies --telemetry;
                                      default TELEMETRY_snapshot.json.
                                      Also routes the periodic JSONL
                                      progress events off stdout into a
                                      sibling FILE with extension
                                      .events.jsonl)
  --telemetry-events FILE            (explicit JSONL event path, implies
                                      --telemetry; overrides the sibling
                                      derivation)
  --no-validate-ingest               (skip the ingest boundary checks —
                                      empty / wrong-dimension /
                                      non-finite batches; on by default
                                      so bad values never reach
                                      fixed-point state)

SERVE OPTIONS:
  --tenants N --shards S             (default 16 tenants on 4 shards)
  --batch B --batches N              (rows per batch / batches per
                                      tenant; default 256 x 32)
  --arrival uniform|skewed[:R]|bursty[:B]
                                     (traffic shape; skewed sends R x
                                      the batches through tenant 0,
                                      default uniform)
  --stages LIST --precision P        (pin every tenant to one graph
                                      shape; default cycles a mixed
                                      f32/q4.12 preset)
  --queue-depth Q --quantum K        (per-tenant ingress depth and
                                      batches per scheduler round)
  --evict-idle                       (checkpoint-evict sessions that saw
                                      no traffic in a round; restores
                                      are transparent and bit-exact)
  --pipeline / --no-pipeline         (two-slot stage/commit pipeline per
                                      shard: next round's validation +
                                      entry quantization overlaps this
                                      round's trainer commits, and
                                      same-plan batches fuse into
                                      mega-tile commits. Bit-identical
                                      to the serial scheduler; default
                                      off, on under --smoke unless
                                      --no-pipeline)
  --telemetry                        (per-tenant datapath telemetry in
                                      the report and JSON)
  --inject-faults SPEC               (deterministic fault injection:
                                      comma-separated tenant:kind[@rate]
                                      with kind nan|inf|dim|empty|stall|
                                      ingest|restore and tenant t<N> or
                                      `*`; e.g. \"t1:nan,t3:ingest@0.5\".
                                      Faulting tenants are retried with
                                      bounded backoff, then quarantined
                                      on their last-good checkpoint —
                                      other tenants are unaffected)
  --json FILE                        (write the schema-validated
                                      SERVE_report.json)
  --smoke                            (CI sizes: 8 tenants, 2 shards,
                                      mixed graphs, telemetry on)
  --seed S
";

/// Load a dataset by CLI name, standardised (zero mean / unit variance
/// on training statistics), matching the paper's preprocessing.
pub fn load_dataset(name: &str, seed: u64) -> Result<Dataset> {
    let mut d = match name {
        "waveform" => WaveformConfig {
            seed,
            ..WaveformConfig::paper()
        }
        .generate(),
        "mnist" => MnistLikeConfig {
            train: 3000,
            test: 800,
            seed,
            ..Default::default()
        }
        .generate(),
        "har" => HarLikeConfig {
            train: 2000,
            test: 500,
            seed,
        }
        .generate(),
        "ads" => AdsLikeConfig {
            train: 2000,
            test: 500,
            seed,
            ..Default::default()
        }
        .generate(),
        other => {
            if let Some(path) = other.strip_prefix("csv:") {
                dimred::datasets::csv::load_csv(Path::new(path), "csv", 0.8)?
            } else {
                bail!("unknown dataset '{other}' (waveform|mnist|har|ads|csv:<path>)")
            }
        }
    };
    d.standardize();
    Ok(d)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    cfg.apply_args(args)?;
    let data = load_dataset(&cfg.dataset, cfg.seed)?;
    anyhow::ensure!(
        data.input_dim() == cfg.input_dim,
        "dataset '{}' has m={}, but config says {} (pass --input-dim {})",
        cfg.dataset,
        data.input_dim(),
        cfg.input_dim,
        data.input_dim()
    );

    let runtime = match cfg.backend {
        Backend::Pjrt => Some(
            Runtime::load(&cfg.artifact_dir)
                .context("loading artifacts (run `make artifacts`)")?,
        ),
        Backend::Native => None,
    };
    if let Some(rt) = &runtime {
        println!("# PJRT platform: {}", rt.platform());
    }
    println!(
        "# train: dataset={} mode={} backend={:?} precision={} m={} p={} n={} mu={} epochs={} batch={}",
        cfg.dataset,
        cfg.mode.label(),
        cfg.backend,
        cfg.precision.label(),
        cfg.input_dim,
        cfg.intermediate_dim,
        cfg.output_dim,
        cfg.mu,
        cfg.epochs,
        cfg.batch
    );
    if let Some(s) = &cfg.stages {
        println!("# stages: {s}");
    }

    let mut svc = TrainingService::new(cfg.clone(), runtime.as_ref());
    let report = svc.run(&data)?;
    println!("# {}", report.metrics.summary());
    println!(
        "# final update magnitude: {:.3e}",
        report.final_update_magnitude
    );
    for (samples, mag) in &report.metrics.convergence_trace {
        println!("trace {samples} {mag:.6}");
    }
    if let Some(acc) = report.test_accuracy {
        println!("test_accuracy {:.4}", acc);
    }
    if cfg.telemetry {
        let path = cfg.telemetry_out.clone();
        write_telemetry_snapshot(&cfg, &report, &path)?;
    }
    Ok(())
}

/// Validate-then-write the end-of-run telemetry snapshot (the same
/// golden-schema discipline as `BENCH_throughput.json`).
fn write_telemetry_snapshot(
    cfg: &ExperimentConfig,
    report: &dimred::coordinator::TrainReport,
    path: &Path,
) -> Result<()> {
    let snap = report
        .telemetry
        .as_ref()
        .context("run was not instrumented (PJRT backend exposes no datapath telemetry)")?;
    let json = dimred::telemetry::snapshot::to_json(cfg.to_json(), &report.metrics, snap);
    let text = json.to_string_pretty();
    dimred::telemetry::snapshot::validate(&dimred::util::json::Json::parse(&text)?)
        .context("TELEMETRY_snapshot schema self-check")?;
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig {
            // Profiling run: the DR datapath is the subject, the
            // classifier is not (re-enable via a config file if wanted).
            train_classifier: false,
            ..Default::default()
        },
    };
    cfg.apply_args(args)?;
    cfg.telemetry = true;
    anyhow::ensure!(
        cfg.backend == Backend::Native,
        "report instruments the native datapath (the PJRT executables expose no telemetry)"
    );
    let data = load_dataset(&cfg.dataset, cfg.seed)?;
    anyhow::ensure!(
        data.input_dim() == cfg.input_dim,
        "dataset '{}' has m={}, but config says {} (pass --input-dim {})",
        cfg.dataset,
        data.input_dim(),
        cfg.input_dim,
        data.input_dim()
    );
    println!(
        "# report: dataset={} mode={} precision={} m={} p={} n={} epochs={} batch={}",
        cfg.dataset,
        cfg.mode.label(),
        cfg.precision.label(),
        cfg.input_dim,
        cfg.intermediate_dim,
        cfg.output_dim,
        cfg.epochs,
        cfg.batch
    );
    if let Some(s) = &cfg.stages {
        println!("# stages: {s}");
    }
    let mut svc = TrainingService::new(cfg.clone(), None);
    let report = svc.run(&data)?;
    let snap = report
        .telemetry
        .as_ref()
        .context("instrumented run produced no telemetry")?;
    println!("{}", dimred::telemetry::report::render(&report.metrics, snap));
    if let Some(path) = args.opt_str("json") {
        write_telemetry_snapshot(&cfg, &report, Path::new(path))?;
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let backend = Backend::parse(&args.str_or("backend", "native"))?;
    let epochs = args.usize_or("epochs", 8)?;
    let seed = args.u64_or("seed", 2018)?;
    let artifact_dir = args.str_or("artifacts", "artifacts");
    let runtime = match backend {
        Backend::Pjrt => Some(Runtime::load(Path::new(&artifact_dir))?),
        Backend::Native => None,
    };
    let rows = dimred::experiments::table1::run(runtime.as_ref(), backend, epochs, seed)?;
    println!("{}", dimred::experiments::table1::render(&rows));
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let rows = table_ii(&paper_table_ii_configs());
    println!("Table II — hardware cost (model) vs paper, fp32 datapath");
    println!(
        "{:<40} {:>8} {:>10} {:>12}   {:>8} {:>10} {:>12}",
        "configuration", "DSPs", "ALMs", "reg bits", "paper", "paper", "paper"
    );
    for (row, paper) in rows.iter().zip(PAPER_TABLE_II.iter()) {
        let cfg = match row.intermediate {
            Some(p) => HwConfig::rp_easi(row.input, p, row.output),
            None => HwConfig::easi(row.input, row.output),
        };
        println!(
            "{:<40} {:>8} {:>10} {:>12}   {:>8} {:>10} {:>12}",
            cfg.label(),
            row.dsps,
            row.alms,
            row.register_bits,
            paper.0,
            paper.1,
            paper.2
        );
    }

    // Bitwidth-aware section: the same operator inventories priced at
    // fixed-point operand widths — the mechanism behind the paper's
    // resource savings. `--precision qI.F` selects one format;
    // otherwise show a 16/18-bit comparison.
    let formats: Vec<NumericFormat> = match args.opt_str("precision") {
        Some(s) => {
            let p = Precision::parse(s)?;
            anyhow::ensure!(p.is_fixed(), "--precision for table2 expects a Q format");
            vec![NumericFormat::from_precision(&p)]
        }
        None => vec![
            NumericFormat::Fixed { width_bits: 16 },
            NumericFormat::Fixed { width_bits: 18 },
        ],
    };
    println!("\nfixed-point pricing (same datapaths, bitwidth-aware model)");
    println!(
        "{:<40} {:>8} {:>10} {:>12}   {:>9}",
        "configuration", "DSPs", "ALMs", "reg bits", "DSP ratio"
    );
    for base in paper_table_ii_configs() {
        let fp = dimred::hwmodel::Arria10Model::paper_calibrated().cost(&base);
        for fmt in &formats {
            let cfg = base.with_format(*fmt);
            let r = dimred::hwmodel::Arria10Model::paper_calibrated().cost(&cfg);
            println!(
                "{:<40} {:>8} {:>10} {:>12}   {:>8.2}x",
                cfg.label(),
                r.dsps,
                r.alms,
                r.register_bits,
                fp.dsps as f64 / r.dsps.max(1) as f64
            );
        }
    }
    Ok(())
}

fn cmd_fxp_sweep(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("waveform");
    let formats: Vec<Precision> = match args.opt_str("formats") {
        Some(list) => {
            let parsed = list
                .split(',')
                .map(Precision::parse)
                .collect::<Result<Vec<_>>>()?;
            for p in &parsed {
                anyhow::ensure!(
                    p.is_fixed(),
                    "--formats expects Q formats (the f32 baseline is always included)"
                );
            }
            parsed
        }
        None => dimred::experiments::fxp_sweep::default_formats(),
    };
    let (_, _, _, default_epochs) = dimred::experiments::fxp_sweep::dims_for(which)?;
    let epochs = args.usize_or("epochs", default_epochs)?;
    let seed = args.u64_or("seed", 2018)?;
    let stages = args.opt_str("stages");
    if let Some(s) = stages {
        println!("# stages: {s}");
    }
    let points = dimred::experiments::fxp_sweep::run_with(which, &formats, epochs, seed, stages)?;
    println!(
        "{}",
        dimred::experiments::fxp_sweep::render(which, &points)
    );
    if let Some(path) = args.opt_str("json") {
        let json = dimred::experiments::fxp_sweep::to_json(which, &points);
        std::fs::write(path, json.to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("waveform");
    let plans: Vec<Precision> = match args.opt_str("plans") {
        // `;`-separated precision strings — the plan syntax itself uses
        // commas (rp=q8.16,whiten=q4.12,...).
        Some(list) => {
            let parsed = list
                .split(';')
                .filter(|s| !s.trim().is_empty())
                .map(Precision::parse)
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(!parsed.is_empty(), "--plans named no precision plans");
            parsed
        }
        None => dimred::experiments::pareto::default_plans(),
    };
    let (_, _, _, default_epochs) = dimred::experiments::fxp_sweep::dims_for(which)?;
    let epochs = args.usize_or("epochs", default_epochs)?;
    let seed = args.u64_or("seed", 2018)?;
    let stages = args.opt_str("stages");
    if let Some(s) = stages {
        println!("# stages: {s}");
    }
    let points = dimred::experiments::pareto::run_with(which, &plans, epochs, seed, stages)?;
    println!("{}", dimred::experiments::pareto::render(which, &points));
    if let Some(path) = args.opt_str("json") {
        let json = dimred::experiments::pareto::to_json(which, &points);
        std::fs::write(path, json.to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let opts = dimred::experiments::bench::BenchOptions {
        datasets: args
            .str_or("datasets", "waveform,har")
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().to_string())
            .collect(),
        tile: args.usize_or("tile", 256)?,
        lanes: args.usize_or("lanes", 4)?,
        smoke: args.flag("smoke"),
        seed: args.u64_or("seed", 2018)?,
    };
    let results = dimred::experiments::bench::run(&opts)?;
    println!("{}", dimred::experiments::bench::render(&opts, &results));
    let path = args.str_or("json", "BENCH_throughput.json");
    let json = dimred::experiments::bench::to_json(&opts, &results);
    let text = json.to_string_pretty();
    // Self-check against the golden schema before anything downstream
    // (CI, cross-PR diffs) consumes the file.
    dimred::experiments::bench::validate(&dimred::util::json::Json::parse(&text)?)
        .context("BENCH_throughput schema self-check")?;
    std::fs::write(&path, text).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use dimred::serve::workload::{ArrivalPattern, ServeOptions};
    let smoke = args.flag("smoke");
    // Smoke: small enough for CI, mixed f32/fxp graphs (the preset),
    // telemetry on so the report carries per-tenant health to validate.
    let defaults = if smoke {
        ServeOptions {
            tenants: 8,
            shards: 2,
            batch: 64,
            batches_per_tenant: 4,
            telemetry: true,
            ..ServeOptions::default()
        }
    } else {
        ServeOptions::default()
    };
    let opts = ServeOptions {
        tenants: args.usize_or("tenants", defaults.tenants)?,
        shards: args.usize_or("shards", defaults.shards)?,
        batch: args.usize_or("batch", defaults.batch)?,
        batches_per_tenant: args.usize_or("batches", defaults.batches_per_tenant)?,
        queue_depth: args.usize_or("queue-depth", defaults.queue_depth)?,
        quantum: args.usize_or("quantum", defaults.quantum)?,
        arrival: ArrivalPattern::parse(&args.str_or("arrival", "uniform"))?,
        stages: args.opt_str("stages").map(str::to_string),
        precision: args.opt_str("precision").map(str::to_string),
        telemetry: defaults.telemetry || args.flag("telemetry"),
        evict_idle: args.flag("evict-idle"),
        // Smoke runs default to the pipelined scheduler so CI exercises
        // the stage/commit overlap path; --no-pipeline always wins.
        pipeline: (smoke || args.flag("pipeline")) && !args.flag("no-pipeline"),
        seed: args.u64_or("seed", defaults.seed)?,
        faults: args.opt_str("inject-faults").map(str::to_string),
    };
    println!(
        "# serve: tenants={} shards={} batch={} batches/tenant={} arrival={}{}{}{}",
        opts.tenants,
        opts.shards,
        opts.batch,
        opts.batches_per_tenant,
        opts.arrival.label(),
        if opts.pipeline { " pipeline" } else { "" },
        opts.faults
            .as_deref()
            .map(|f| format!(" faults={f}"))
            .unwrap_or_default(),
        if smoke { " (smoke)" } else { "" }
    );
    let report = dimred::serve::workload::run(&opts)?;
    print!("{}", dimred::serve::report::render(&report));
    if let Some(path) = args.opt_str("json") {
        let json = dimred::serve::report::to_json(&opts, &report);
        let text = json.to_string_pretty();
        // Self-check against the golden schema — with telemetry on this
        // also validates every tenant's health snapshot, which is what
        // the CI smoke step relies on.
        dimred::serve::report::validate(&dimred::util::json::Json::parse(&text)?, opts.telemetry)
            .context("SERVE_report schema self-check")?;
        std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("mnist");
    let points = args.usize_or("points", 5)?;
    let seed = args.u64_or("seed", 2018)?;
    let series = dimred::experiments::fig1::run(which, points, seed)?;
    println!("{}", dimred::experiments::fig1::render(which, &series));
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = dimred::runtime::Manifest::load(Path::new(&dir))?;
    println!("{} artifacts in {}", manifest.artifacts.len(), dir);
    for (name, spec) in &manifest.artifacts {
        let ins: Vec<String> = spec.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {:<36} inputs {} — {}", name, ins.join(" "), spec.description);
    }
    Ok(())
}

fn cmd_timing(args: &Args) -> Result<()> {
    let m = args.usize_or("input-dim", 32)?;
    let p = args.usize_or("intermediate-dim", 16)?;
    let n = args.usize_or("output-dim", 8)?;
    let model = PipelineModel::default();
    for cfg in [HwConfig::easi(m, n), HwConfig::rp_easi(m, p, n)] {
        let t = model.timing(&cfg);
        println!(
            "{:<28} f_clk {:.2} MHz  throughput {:.2} Msamples/s  latency {} cycles ({:.1} ns)",
            cfg.label(),
            t.f_clk_hz / 1e6,
            t.throughput_samples_per_s / 1e6,
            t.latency_cycles,
            t.latency_ns
        );
    }
    Ok(())
}
