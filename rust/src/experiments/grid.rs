//! Shared precision-grid harness — the single evaluation path behind
//! `fxp-sweep` and `pareto` (previously duplicated between the two),
//! now expressed over stage graphs so any cascade — not just the
//! paper's RP → unit shape — sweeps, prices and classifies with zero
//! new plumbing.
//!
//! One grid point = fit a [`GraphSpec`] at one [`Precision`] on a
//! dataset, train the paper's 2×64 classifier on the reduced features,
//! and join the test accuracy with the graph's per-stage Arria-10 price
//! ([`GraphSpec::hw_cost`] — bit-identical to the historical
//! `cost_precision` numbers for the legacy shapes).

use crate::datasets::{har_like::HarLikeConfig, waveform::WaveformConfig, Dataset};
use crate::fxp::Precision;
use crate::hwmodel::Arria10Model;
use crate::mlp::{Mlp, MlpConfig};
use crate::rp::RpDistribution;
use crate::stage::spec::parse_stage_list;
use crate::stage::{GraphSpec, StageDecl, StageOp};
use anyhow::{bail, Result};

/// One grid point: a precision, its accuracy, and its hardware price.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// `"f32"` or the precision-plan label.
    pub precision: String,
    /// Operand width in bits (32 for f32, widest stage for plans).
    pub width_bits: u8,
    /// Test accuracy, percent.
    pub accuracy: f64,
    /// Arria-10 cost of the stage graph at this precision.
    pub dsps: u64,
    pub alms: u64,
    pub register_bits: u64,
}

/// Pipeline dimensions per dataset: `(m, p, n, dr_epochs_default)`.
pub fn dims_for(which: &str) -> Result<(usize, usize, usize, usize)> {
    match which {
        "waveform" => Ok((32, 16, 8, 4)),
        "har" => Ok((561, 64, 16, 2)),
        other => bail!("unknown sweep dataset '{other}' (waveform|har)"),
    }
}

/// The paper's proposed graph at intermediate dim `p`: ternary RP →
/// GHA whitening → EASI rotation.
pub fn proposed_stages(p: usize) -> Vec<StageDecl> {
    vec![
        StageDecl::new(StageOp::Rp(RpDistribution::Ternary)).with_dim(p),
        StageDecl::new(StageOp::WhitenGha),
        StageDecl::new(StageOp::RotEasi),
    ]
}

pub(crate) fn load(which: &str, seed: u64, train: usize, test: usize) -> Result<Dataset> {
    let mut d = match which {
        "waveform" => WaveformConfig {
            samples: train + test,
            train,
            seed,
            ..WaveformConfig::paper()
        }
        .generate(),
        "har" => HarLikeConfig { train, test, seed }.generate(),
        other => bail!("unknown sweep dataset '{other}'"),
    };
    d.standardize();
    Ok(d)
}

/// Paper-scale dataset splits per dataset: `(train, test)` — shared so
/// the precision experiments always evaluate on identical splits.
pub(crate) fn paper_splits(which: &str) -> (usize, usize) {
    match which {
        "har" => (2000, 500),
        _ => (4000, 1000),
    }
}

/// Classifier epochs for paper-scale runs (§V.B protocol).
pub(crate) const PAPER_MLP_EPOCHS: usize = 30;

/// Train the paper's 2×64 classifier on reduced features, return test
/// accuracy in percent (paper §V.B protocol).
pub(crate) fn classify(reduced: &Dataset, seed: u64, epochs: usize) -> f64 {
    let mut reduced = reduced.clone();
    reduced.standardize();
    let mut mlp = Mlp::new(MlpConfig {
        epochs,
        seed,
        ..MlpConfig::paper(reduced.input_dim(), reduced.num_classes)
    });
    mlp.train(&reduced.train_x, &reduced.train_y);
    mlp.accuracy(&reduced.test_x, &reduced.test_y) * 100.0
}

/// Evaluate one (graph, precision) point on an already-loaded dataset.
/// The graph fit and the classifier init get *sub-seeds* derived from
/// the master seed (tags 1 and 2; the data draw is the caller's, tag 0
/// = the master itself), so the classifier's init noise is not
/// correlated with the data draw across sweep points.
pub(crate) fn eval_point(
    data: &Dataset,
    dims: (usize, usize, usize),
    stages: &[StageDecl],
    precision: Precision,
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
) -> Result<SweepPoint> {
    let (m, _p, n) = dims;
    let pipe_seed = crate::rng::derive_seed(seed, 1);
    let mlp_seed = crate::rng::derive_seed(seed, 2);
    let gspec = GraphSpec {
        input_dim: m,
        output_dim: n,
        stages: stages.to_vec(),
        seed: pipe_seed,
        precision,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rot_warmup: None,
        epochs: dr_epochs,
    };
    let mut graph = gspec.build(Some(data.train_x.rows_count()))?;
    graph.fit(&data.train_x, dr_epochs);
    let reduced = Dataset {
        name: format!("{}+dr{n}", data.name),
        train_x: graph.transform_rows(&data.train_x),
        train_y: data.train_y.clone(),
        test_x: graph.transform_rows(&data.test_x),
        test_y: data.test_y.clone(),
        num_classes: data.num_classes,
    };
    let accuracy = classify(&reduced, mlp_seed, mlp_epochs);
    // Graph-folded, plan-aware pricing: legacy shapes keep the
    // historical single/per-stage numbers bit-for-bit, arbitrary
    // cascades fold per-stage inventories.
    let cost = gspec.hw_cost(&Arria10Model::paper_calibrated())?;
    Ok(SweepPoint {
        precision: precision.label(),
        width_bits: precision.width_bits(),
        accuracy,
        dsps: cost.dsps,
        alms: cost.alms,
        register_bits: cost.register_bits,
    })
}

/// Evaluate a precision grid over one stage graph (the default is the
/// paper's proposed graph at the dataset's `(m, p, n)`).
pub fn run_grid(
    which: &str,
    precisions: &[Precision],
    stages: Option<&str>,
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
    train: usize,
    test: usize,
) -> Result<Vec<SweepPoint>> {
    let (m, p, n, _) = dims_for(which)?;
    let data = load(which, seed, train, test)?;
    let stages = match stages {
        Some(s) => parse_stage_list(s)?,
        None => proposed_stages(p),
    };
    precisions
        .iter()
        .map(|prec| eval_point(&data, (m, p, n), &stages, *prec, dr_epochs, mlp_epochs, seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_for_known_datasets() {
        assert_eq!(dims_for("waveform").unwrap(), (32, 16, 8, 4));
        assert_eq!(dims_for("har").unwrap().0, 561);
        assert!(dims_for("bogus").is_err());
    }

    #[test]
    fn custom_stage_grid_runs_end_to_end() {
        // The scenario-diversity acceptance: non-paper graphs sweep
        // through the same harness with zero new plumbing.
        for (stages, prec) in [
            ("rp:ternary/16,pca", "f32"),
            ("dct/16,whiten:gha,rot:easi", "f32"),
            ("whiten:gha", "q4.12"),
        ] {
            let pts = run_grid(
                "waveform",
                &[Precision::parse(prec).unwrap()],
                Some(stages),
                1,
                4,
                2018,
                400,
                120,
            )
            .unwrap();
            assert_eq!(pts.len(), 1, "{stages}");
            let pt = &pts[0];
            assert!(pt.accuracy.is_finite() && pt.accuracy > 20.0, "{stages}: {}", pt.accuracy);
            assert!(pt.alms > 0, "{stages} must price nonzero");
        }
    }
}
