//! Table I — classification accuracy on the Waveform dataset for the
//! paper's four configurations (§V.C):
//!
//! | m  | Algorithm 1        | p  | Algorithm 2 | n  | paper acc |
//! |----|--------------------|----|-------------|----|-----------|
//! | 32 | —                  | —  | EASI        | 16 | 84.6      |
//! | 32 | Random Projection  | 24 | EASI        | 16 | 84.5      |
//! | 32 | —                  | —  | EASI        | 8  | 80.9      |
//! | 32 | Random Projection  | 16 | EASI        | 8  | 80.8      |
//!
//! Protocol (paper §V.A/B): waveform m=32, 4000 train / 1000 test;
//! DR stage trained unsupervised by streaming; then a 2×64 MLP is
//! trained on the reduced features and evaluated on the reduced test
//! set. The driver runs through the full coordinator (producer →
//! bounded queue → trainer), on either backend.

use crate::config::{Backend, ExperimentConfig, PipelineMode};
use crate::coordinator::TrainingService;
use crate::datasets::waveform::WaveformConfig;
use crate::runtime::Runtime;
use anyhow::Result;

/// One Table I row: configuration + measured + paper accuracy.
#[derive(Debug, Clone)]
pub struct Row {
    pub m: usize,
    pub algorithm1: Option<&'static str>,
    pub p: Option<usize>,
    pub algorithm2: &'static str,
    pub n: usize,
    pub accuracy: f64,
    pub paper_accuracy: f64,
    /// Training throughput of the DR stage, samples/s.
    pub throughput: f64,
}

/// The paper's four configurations: (mode, p, n, paper accuracy).
pub const CONFIGS: [(PipelineMode, usize, usize, f64); 4] = [
    (PipelineMode::Easi, 0, 16, 84.6),
    (PipelineMode::RpEasi, 24, 16, 84.5),
    (PipelineMode::Easi, 0, 8, 80.9),
    (PipelineMode::RpEasi, 16, 8, 80.8),
];

/// Run all four configurations. `runtime` is required for
/// [`Backend::Pjrt`].
pub fn run(
    runtime: Option<&Runtime>,
    backend: Backend,
    epochs: usize,
    seed: u64,
) -> Result<Vec<Row>> {
    let mut data = WaveformConfig {
        seed,
        ..WaveformConfig::paper()
    }
    .generate();
    data.standardize();

    let mut rows = Vec::with_capacity(CONFIGS.len());
    for &(mode, p, n, paper) in &CONFIGS {
        let cfg = ExperimentConfig {
            dataset: "waveform".into(),
            input_dim: 32,
            intermediate_dim: if p == 0 { n } else { p },
            output_dim: n,
            mode,
            backend,
            epochs,
            mlp_epochs: 30,
            seed,
            ..Default::default()
        };
        let mut svc = TrainingService::new(cfg, runtime);
        let report = svc.run(&data)?;
        rows.push(Row {
            m: 32,
            algorithm1: (mode == PipelineMode::RpEasi).then_some("Random Projection"),
            p: (mode == PipelineMode::RpEasi).then_some(p),
            algorithm2: "EASI",
            n,
            accuracy: report.test_accuracy.expect("classifier enabled") * 100.0,
            paper_accuracy: paper,
            throughput: report.metrics.throughput(),
        });
    }
    Ok(rows)
}

/// Render rows in the paper's format plus the measured column.
pub fn render(rows: &[Row]) -> String {
    let mut out = String::from(
        "Table I — classification accuracy (waveform, 4000/1000 split)\n",
    );
    out.push_str(&format!(
        "{:<4} {:<19} {:<4} {:<11} {:<4} {:>9} {:>9} {:>14}\n",
        "m", "Algorithm 1", "p", "Algorithm 2", "n", "acc (%)", "paper", "DR samples/s"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<4} {:<19} {:<4} {:<11} {:<4} {:>9.1} {:>9.1} {:>14.0}\n",
            r.m,
            r.algorithm1.unwrap_or("-"),
            r.p.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            r.algorithm2,
            r.n,
            r.accuracy,
            r.paper_accuracy,
            r.throughput,
        ));
    }
    out
}

/// Shape assertions used by tests and the bench harness (DESIGN.md §5,
/// revised per EXPERIMENTS.md §Discrepancies): equal-n configurations
/// within `pair_tol` accuracy points of each other, every row within a
/// 12-point band of the paper, and all far above chance. The paper's
/// n=16 > n=8 ordering is NOT enforced — on a fresh waveform draw the
/// extra whitened noise dimensions slightly hurt the small classifier
/// (batch PCA shows the same inversion), so the ordering is a property
/// of the authors' particular draw, not of the algorithms.
pub fn check_shape(rows: &[Row], pair_tol: f64) -> Result<()> {
    anyhow::ensure!(rows.len() == 4, "expected 4 rows");
    let d16 = (rows[0].accuracy - rows[1].accuracy).abs();
    let d8 = (rows[2].accuracy - rows[3].accuracy).abs();
    anyhow::ensure!(
        d16 <= pair_tol,
        "n=16: EASI vs RP+EASI differ by {d16:.2} pts (tol {pair_tol})"
    );
    anyhow::ensure!(
        d8 <= pair_tol,
        "n=8: EASI vs RP+EASI differ by {d8:.2} pts (tol {pair_tol})"
    );
    for r in rows {
        anyhow::ensure!(
            (r.accuracy - r.paper_accuracy).abs() <= 13.0,
            "n={} p={:?}: measured {:.1} vs paper {:.1} out of band",
            r.n,
            r.p,
            r.accuracy,
            r.paper_accuracy
        );
        anyhow::ensure!(r.accuracy > 60.0, "accuracy {:.1} too close to chance", r.accuracy);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_table1_reproduces_paper_shape() {
        // Full-protocol run on the native backend (PJRT covered by
        // integration tests + the example). Acceptance criteria per
        // DESIGN.md section 5 / EXPERIMENTS.md section Discrepancies:
        // the EASI-only rows land inside a 6-point band of the paper;
        // the RP rows are bounded by the information an actual random
        // projection retains (the batch-PCA oracle on the RP image caps
        // at ~69-76% here), so the pair tolerance is wider.
        let rows = run(None, Backend::Native, 6, 2018).unwrap();
        check_shape(&rows, 13.0).unwrap();
        for r in &rows {
            assert!(r.accuracy > 60.0, "config n={} p={:?}: accuracy {:.1} too low", r.n, r.p, r.accuracy);
        }
        // EASI-only rows: close to the paper's absolute numbers.
        assert!((rows[0].accuracy - rows[0].paper_accuracy).abs() < 11.0, "easi16 {:.1}", rows[0].accuracy);
        assert!((rows[2].accuracy - rows[2].paper_accuracy).abs() < 6.0, "easi8 {:.1}", rows[2].accuracy);
    }

    #[test]
    fn render_contains_all_rows() {
        let rows = vec![Row {
            m: 32,
            algorithm1: None,
            p: None,
            algorithm2: "EASI",
            n: 16,
            accuracy: 84.2,
            paper_accuracy: 84.6,
            throughput: 1e5,
        }];
        let s = render(&rows);
        assert!(s.contains("84.2"));
        assert!(s.contains("84.6"));
    }
}
