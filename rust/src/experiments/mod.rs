//! Shared experiment drivers — the single source of truth for the
//! paper's tables and figures, used by the CLI (`dimred table1`, ...),
//! the runnable examples and the bench harnesses, so every entry point
//! reports the same numbers.

pub mod bench;
pub mod fig1;
pub mod fxp_sweep;
pub mod grid;
pub mod pareto;
pub mod table1;
