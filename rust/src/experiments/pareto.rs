//! Accuracy/cost Pareto frontier over precision plans — the codesign
//! artifact the paper's resource-savings claim rests on.
//!
//! `fxp_sweep` walks *uniform* formats along one width axis; this
//! experiment sweeps full [`PrecisionPlan`]s (per-stage mixed precision
//! × training mode), joins each point's waveform/HAR accuracy with its
//! per-stage bitwidth-aware Arria-10 cost
//! ([`Arria10Model::cost_precision`](crate::hwmodel::Arria10Model::cost_precision)),
//! and computes the non-dominated frontier: maximise accuracy, minimise
//! DSPs and ALMs. The headline check — *a mixed-precision STE-trained
//! point matching the uniform bit-exact point's accuracy at strictly
//! lower DSPs and ALMs* ([`find_domination`]) — is exactly the paper's
//! "50% resource savings with no accuracy degradation", demonstrated
//! rather than asserted.
//!
//! CLI: `dimred pareto [waveform|har] [--plans "q4.12;rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste"]
//! [--epochs E] [--seed S] [--json FILE]` — plans are `;`-separated
//! [`Precision`] strings (the plan syntax itself uses commas); text
//! report to stdout, JSON to the given path.

use crate::experiments::grid;
use crate::fxp::{Precision, QuantMode};
use crate::util::json::Json;
use anyhow::Result;

/// One evaluated plan: precision, training mode, accuracy, and its
/// per-stage hardware price.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// Canonical precision label (round-trips through
    /// [`Precision::parse`]).
    pub plan: String,
    /// `"f32"`, `"bit-exact"` or `"ste"`.
    pub quant: String,
    /// Whether the plan assigns different formats per stage.
    pub mixed: bool,
    /// Widest stage width in bits (32 for f32).
    pub width_bits: u8,
    /// Test accuracy, percent.
    pub accuracy: f64,
    pub dsps: u64,
    pub alms: u64,
    pub register_bits: u64,
    /// Set by [`mark_frontier`]: no other point dominates this one.
    pub on_frontier: bool,
}

impl ParetoPoint {
    fn from_sweep(precision: &Precision, sp: grid::SweepPoint) -> Self {
        let (quant, mixed) = match precision {
            Precision::F32 => ("f32", false),
            Precision::Fixed(plan) => (plan.quant.label(), !plan.is_uniform()),
        };
        Self {
            plan: sp.precision,
            quant: quant.to_string(),
            mixed,
            width_bits: sp.width_bits,
            accuracy: sp.accuracy,
            dsps: sp.dsps,
            alms: sp.alms,
            register_bits: sp.register_bits,
            on_frontier: false,
        }
    }
}

/// The default plan grid: the f32 reference, uniform bit-exact and STE
/// formats, and the mixed wide-RP/narrow-stage plans real datapaths
/// deploy. Includes the acceptance pair — uniform bit-exact `q8.16`
/// vs `rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste` (same RP accumulator
/// width, half-DSP trained stage).
pub fn default_plans() -> Vec<Precision> {
    [
        "f32",
        "q4.8",
        "q4.12",
        "q8.16",
        "q4.8,qat=ste",
        "q4.12,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste",
        "rp=q8.16,whiten=q4.8,rot=q4.8,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q1.15,qat=ste",
    ]
    .iter()
    .map(|s| Precision::parse(s).expect("static plan"))
    .collect()
}

/// Mark the non-dominated set: point `a` dominates `b` when it is at
/// least as accurate AND at most as expensive on both DSPs and ALMs,
/// strictly better on at least one of the three.
pub fn mark_frontier(points: &mut [ParetoPoint]) {
    let snapshot: Vec<(f64, u64, u64)> =
        points.iter().map(|p| (p.accuracy, p.dsps, p.alms)).collect();
    for (i, p) in points.iter_mut().enumerate() {
        let (acc, dsps, alms) = snapshot[i];
        p.on_frontier = !snapshot.iter().enumerate().any(|(j, &(a, d, l))| {
            j != i
                && a >= acc
                && d <= dsps
                && l <= alms
                && (a > acc || d < dsps || l < alms)
        });
    }
}

/// The acceptance check behind the paper's claim: find a
/// mixed-precision STE-trained point whose accuracy matches a uniform
/// bit-exact fixed-point point within `tol` percentage points at
/// strictly lower DSPs *and* ALMs. Returns `(mixed_label,
/// uniform_label)` for the first (widest-savings) such pair.
pub fn find_domination(points: &[ParetoPoint], tol: f64) -> Option<(String, String)> {
    let mut best: Option<(u64, String, String)> = None;
    for a in points.iter().filter(|p| p.mixed && p.quant == "ste") {
        for b in points
            .iter()
            .filter(|p| !p.mixed && p.quant == QuantMode::BitExact.label())
        {
            if a.accuracy + tol >= b.accuracy && a.dsps < b.dsps && a.alms < b.alms {
                let saving = b.dsps - a.dsps;
                if best.as_ref().map_or(true, |(s, _, _)| saving > *s) {
                    best = Some((saving, a.plan.clone(), b.plan.clone()));
                }
            }
        }
    }
    best.map(|(_, a, b)| (a, b))
}

/// Run the sweep at custom dataset sizes (tests use reduced splits).
pub fn run_sized(
    which: &str,
    plans: &[Precision],
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
    train: usize,
    test: usize,
) -> Result<Vec<ParetoPoint>> {
    run_sized_stages(which, plans, None, dr_epochs, mlp_epochs, seed, train, test)
}

/// [`run_sized`] over an explicit stage graph (`None` = the paper's
/// proposed cascade) — the shared grid harness does the evaluation, so
/// `pareto` and `fxp-sweep` can never drift apart.
pub fn run_sized_stages(
    which: &str,
    plans: &[Precision],
    stages: Option<&str>,
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
    train: usize,
    test: usize,
) -> Result<Vec<ParetoPoint>> {
    let sweep = grid::run_grid(
        which, plans, stages, dr_epochs, mlp_epochs, seed, train, test,
    )?;
    let mut points: Vec<ParetoPoint> = plans
        .iter()
        .zip(sweep)
        .map(|(prec, sp)| ParetoPoint::from_sweep(prec, sp))
        .collect();
    mark_frontier(&mut points);
    Ok(points)
}

/// Run the sweep with the paper-scale dataset splits (shared with
/// `fxp_sweep` so the two precision experiments stay comparable).
pub fn run(which: &str, plans: &[Precision], epochs: usize, seed: u64) -> Result<Vec<ParetoPoint>> {
    run_with(which, plans, epochs, seed, None)
}

/// [`run`] over an explicit stage graph (the `--stages` CLI path).
pub fn run_with(
    which: &str,
    plans: &[Precision],
    epochs: usize,
    seed: u64,
    stages: Option<&str>,
) -> Result<Vec<ParetoPoint>> {
    let (train, test) = grid::paper_splits(which);
    run_sized_stages(
        which,
        plans,
        stages,
        epochs,
        grid::PAPER_MLP_EPOCHS,
        seed,
        train,
        test,
    )
}

/// Accuracy-equality tolerance (percentage points) used by the claim
/// line of the report — the same "within two points" convention the
/// fxp-sweep acceptance test uses.
pub const CLAIM_TOL: f64 = 2.0;

/// Render as an aligned text table: frontier membership, accuracy, and
/// the per-stage cost columns, plus the domination claim line.
pub fn render(which: &str, points: &[ParetoPoint]) -> String {
    let mut out = format!(
        "pareto ({which}) — accuracy vs per-stage hardware cost (frontier marked *)\n"
    );
    out.push_str(&format!(
        "{:<44} {:>9} {:>6} {:>9} {:>8} {:>10} {:>12}\n",
        "plan", "train", "bits", "acc (%)", "DSPs", "ALMs", "reg bits"
    ));
    for p in points {
        out.push_str(&format!(
            "{} {:<42} {:>9} {:>6} {:>9.1} {:>8} {:>10} {:>12}\n",
            if p.on_frontier { "*" } else { " " },
            p.plan,
            p.quant,
            p.width_bits,
            p.accuracy,
            p.dsps,
            p.alms,
            p.register_bits
        ));
    }
    match find_domination(points, CLAIM_TOL) {
        Some((mixed, uniform)) => out.push_str(&format!(
            "claim: mixed-precision STE plan '{mixed}' matches uniform bit-exact \
             '{uniform}' within {CLAIM_TOL} points at lower DSPs and ALMs\n"
        )),
        None => out.push_str(
            "claim: no mixed-precision STE plan dominates a uniform bit-exact point\n",
        ),
    }
    out
}

/// Serialise the sweep for downstream plotting / the golden-schema
/// test: `experiment`, `dataset`, `pipeline`, `points[]` (with
/// `on_frontier`), `frontier[]` (labels), and the `claim` object.
pub fn to_json(which: &str, points: &[ParetoPoint]) -> Json {
    let (m, p, n, _) = grid::dims_for(which).unwrap_or((0, 0, 0, 0));
    let claim = match find_domination(points, CLAIM_TOL) {
        Some((mixed, uniform)) => Json::obj(vec![
            ("holds", Json::Bool(true)),
            ("mixed_ste", Json::str(mixed)),
            ("uniform_bit_exact", Json::str(uniform)),
            ("accuracy_tolerance", Json::num(CLAIM_TOL)),
        ]),
        None => Json::obj(vec![
            ("holds", Json::Bool(false)),
            ("accuracy_tolerance", Json::num(CLAIM_TOL)),
        ]),
    };
    Json::obj(vec![
        ("experiment", Json::str("pareto")),
        ("dataset", Json::str(which)),
        (
            "pipeline",
            Json::obj(vec![
                ("input_dim", Json::num(m as f64)),
                ("intermediate_dim", Json::num(p as f64)),
                ("output_dim", Json::num(n as f64)),
                ("stage", Json::str("rp-ternary + gha-whiten + easi-rotate")),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|pt| {
                        Json::obj(vec![
                            ("plan", Json::str(pt.plan.clone())),
                            ("quant", Json::str(pt.quant.clone())),
                            ("mixed", Json::Bool(pt.mixed)),
                            ("width_bits", Json::num(pt.width_bits as f64)),
                            ("accuracy", Json::num(pt.accuracy)),
                            ("dsps", Json::num(pt.dsps as f64)),
                            ("alms", Json::num(pt.alms as f64)),
                            ("register_bits", Json::num(pt.register_bits as f64)),
                            ("on_frontier", Json::Bool(pt.on_frontier)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "frontier",
            Json::Arr(
                points
                    .iter()
                    .filter(|p| p.on_frontier)
                    .map(|p| Json::str(p.plan.clone()))
                    .collect(),
            ),
        ),
        ("claim", claim),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(plan: &str, quant: &str, mixed: bool, acc: f64, dsps: u64, alms: u64) -> ParetoPoint {
        ParetoPoint {
            plan: plan.into(),
            quant: quant.into(),
            mixed,
            width_bits: 16,
            accuracy: acc,
            dsps,
            alms,
            register_bits: 10_000,
            on_frontier: false,
        }
    }

    #[test]
    fn frontier_marks_non_dominated_points() {
        let mut pts = vec![
            point("f32", "f32", false, 81.0, 2212, 70031),
            point("q8.16", "bit-exact", false, 80.5, 1700, 30000),
            // Dominated: worse accuracy AND more expensive than q8.16.
            point("q4.12", "bit-exact", false, 79.0, 1800, 31000),
            // Dominates q8.16 on cost at equal-ish accuracy.
            point("mixed", "ste", true, 80.5, 900, 15000),
        ];
        mark_frontier(&mut pts);
        assert!(pts[0].on_frontier, "f32 has the best accuracy");
        assert!(!pts[1].on_frontier, "q8.16 is dominated by the mixed point");
        assert!(!pts[2].on_frontier);
        assert!(pts[3].on_frontier);
    }

    #[test]
    fn domination_requires_mixed_ste_vs_uniform_bit_exact() {
        let mut pts = vec![
            point("f32", "f32", false, 81.0, 2212, 70031),
            point("q8.16", "bit-exact", false, 80.5, 1700, 30000),
            point("mixed", "ste", true, 79.2, 900, 15000),
        ];
        mark_frontier(&mut pts);
        // Within 2 points of q8.16 at lower cost: the claim holds…
        let (a, b) = find_domination(&pts, 2.0).unwrap();
        assert_eq!(a, "mixed");
        assert_eq!(b, "q8.16");
        // …but not at a tolerance the accuracy gap exceeds.
        assert!(find_domination(&pts, 1.0).is_none());
        // f32 never counts as the uniform bit-exact reference.
        let only_f32 = vec![
            point("f32", "f32", false, 81.0, 2212, 70031),
            point("mixed", "ste", true, 80.9, 900, 15000),
        ];
        assert!(find_domination(&only_f32, 2.0).is_none());
    }

    #[test]
    fn json_schema_golden() {
        let mut pts = vec![
            point("q8.16", "bit-exact", false, 80.0, 1700, 30000),
            point("rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste", "ste", true, 79.5, 900, 15000),
        ];
        mark_frontier(&mut pts);
        let j = to_json("waveform", &pts);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        // Golden schema: every consumer-visible field, by name.
        assert_eq!(parsed.field("experiment").unwrap().as_str().unwrap(), "pareto");
        assert_eq!(parsed.field("dataset").unwrap().as_str().unwrap(), "waveform");
        let pipe = parsed.field("pipeline").unwrap();
        assert_eq!(pipe.field("input_dim").unwrap().as_usize().unwrap(), 32);
        assert_eq!(pipe.field("intermediate_dim").unwrap().as_usize().unwrap(), 16);
        assert_eq!(pipe.field("output_dim").unwrap().as_usize().unwrap(), 8);
        let points = parsed.field("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2);
        for (pt, src) in points.iter().zip(&pts) {
            assert_eq!(pt.field("plan").unwrap().as_str().unwrap(), src.plan);
            assert_eq!(pt.field("quant").unwrap().as_str().unwrap(), src.quant);
            assert_eq!(pt.field("mixed").unwrap().as_bool().unwrap(), src.mixed);
            assert_eq!(
                pt.field("width_bits").unwrap().as_usize().unwrap(),
                src.width_bits as usize
            );
            assert!(pt.field("accuracy").unwrap().as_f64().is_ok());
            assert_eq!(pt.field("dsps").unwrap().as_usize().unwrap(), src.dsps as usize);
            assert_eq!(pt.field("alms").unwrap().as_usize().unwrap(), src.alms as usize);
            assert_eq!(
                pt.field("register_bits").unwrap().as_usize().unwrap(),
                src.register_bits as usize
            );
            assert_eq!(
                pt.field("on_frontier").unwrap().as_bool().unwrap(),
                src.on_frontier
            );
        }
        // The mixed point dominates within tolerance → frontier holds
        // it alone, and the claim object names the pair.
        let frontier = parsed.field("frontier").unwrap().as_arr().unwrap();
        assert_eq!(frontier.len(), 2, "both points are non-dominated (acc vs cost)");
        let claim = parsed.field("claim").unwrap();
        assert!(claim.field("holds").unwrap().as_bool().unwrap());
        assert_eq!(
            claim.field("mixed_ste").unwrap().as_str().unwrap(),
            "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste"
        );
        assert_eq!(claim.field("uniform_bit_exact").unwrap().as_str().unwrap(), "q8.16");
        // Every plan label round-trips through Precision::parse.
        for pt in &pts {
            assert!(crate::fxp::Precision::parse(&pt.plan).is_ok());
        }
    }

    #[test]
    fn default_plans_parse_and_cover_the_claim_pair() {
        let plans = default_plans();
        assert!(plans.len() >= 6);
        assert!(plans.iter().any(|p| matches!(p, Precision::F32)));
        let labels: Vec<String> = plans.iter().map(|p| p.label()).collect();
        assert!(labels.iter().any(|l| l == "q8.16"));
        assert!(labels
            .iter()
            .any(|l| l == "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste"));
    }

    #[test]
    fn custom_stage_graph_pareto_runs() {
        // A non-paper graph (whiten-only fixed point) through the same
        // harness: points evaluate, price, and mark a frontier with
        // zero new plumbing.
        let plans = vec![
            Precision::parse("f32").unwrap(),
            Precision::parse("q4.12").unwrap(),
        ];
        let pts =
            run_sized_stages("waveform", &plans, Some("whiten:gha"), 1, 4, 2018, 400, 120)
                .unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.alms > 0));
        assert!(pts.iter().any(|p| p.on_frontier));
    }

    #[test]
    fn mixed_ste_dominates_uniform_bit_exact_on_waveform() {
        // The PR's acceptance criterion, end to end at reduced scale:
        // train the uniform bit-exact q8.16 pipeline and the mixed
        // STE plan (same RP accumulator, 16-bit trained stage), and
        // verify the mixed point matches accuracy within the claim
        // tolerance at strictly lower DSPs and ALMs.
        let plans = vec![
            Precision::parse("q8.16").unwrap(),
            Precision::parse("rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste").unwrap(),
        ];
        let pts = run_sized("waveform", &plans, 3, 25, 2018, 2500, 600).unwrap();
        assert_eq!(pts.len(), 2);
        let (uni, mixed) = (&pts[0], &pts[1]);
        assert!(mixed.dsps < uni.dsps, "{} vs {}", mixed.dsps, uni.dsps);
        assert!(mixed.alms < uni.alms);
        assert!(
            mixed.accuracy + CLAIM_TOL >= uni.accuracy,
            "mixed STE {:.1} vs uniform bit-exact {:.1}",
            mixed.accuracy,
            uni.accuracy
        );
        assert!(uni.accuracy > 60.0, "baseline degenerate: {}", uni.accuracy);
        let (a, b) = find_domination(&pts, CLAIM_TOL).expect("claim must hold");
        assert_eq!(a, mixed.plan);
        assert_eq!(b, uni.plan);
        // The dominated uniform point cannot be on the frontier when
        // the mixed point beats it on cost at comparable accuracy —
        // unless it strictly wins on accuracy, which the tolerance
        // above allows; either way the mixed point must be frontier.
        assert!(mixed.on_frontier || mixed.accuracy < uni.accuracy);
    }
}
