//! Accuracy-vs-bitwidth sweep — the precision analogue of Fig. 1.
//!
//! Trains a stage graph (default: the paper's proposed ternary-RP →
//! whiten → rotate cascade; any `--stages` list otherwise) at a grid of
//! fixed-point formats plus the f32 reference, on the waveform or
//! HAR-like dataset, and reports per-point test accuracy alongside the
//! bitwidth-aware Arria-10 resource cost ([`crate::hwmodel`]). This is
//! the artifact the precision claim rests on: where on the width axis
//! accuracy is flat while DSPs/ALMs/registers fall.
//!
//! The evaluation loop is the shared grid harness
//! ([`crate::experiments::grid`], also behind `pareto`), so the two
//! precision experiments can never drift apart.
//!
//! CLI: `dimred fxp-sweep [waveform|har] [--formats q4.4,q4.8,q4.12]
//! [--stages LIST] [--epochs E] [--seed S] [--json FILE]` — text table
//! to stdout, JSON to the given path.

use super::grid;
use crate::fxp::Precision;
use crate::util::json::Json;
use anyhow::Result;

pub use super::grid::{dims_for, SweepPoint};

/// The default format grid: 8 → 18 bits with 4 integer bits (enough
/// headroom for standardized data without prescaling).
pub fn default_formats() -> Vec<Precision> {
    ["q4.4", "q4.8", "q4.12", "q4.14"]
        .iter()
        .map(|s| Precision::parse(s).expect("static format"))
        .collect()
}

/// Run the sweep at custom dataset sizes (tests use reduced splits).
pub fn run_sized(
    which: &str,
    formats: &[Precision],
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
    train: usize,
    test: usize,
) -> Result<Vec<SweepPoint>> {
    run_sized_stages(which, formats, None, dr_epochs, mlp_epochs, seed, train, test)
}

/// [`run_sized`] over an explicit stage graph (`None` = the paper's
/// proposed cascade).
pub fn run_sized_stages(
    which: &str,
    formats: &[Precision],
    stages: Option<&str>,
    dr_epochs: usize,
    mlp_epochs: usize,
    seed: u64,
    train: usize,
    test: usize,
) -> Result<Vec<SweepPoint>> {
    // f32 reference first, then the fixed formats ascending by width.
    let mut precisions = vec![Precision::F32];
    precisions.extend_from_slice(formats);
    grid::run_grid(
        which,
        &precisions,
        stages,
        dr_epochs,
        mlp_epochs,
        seed,
        train,
        test,
    )
}

/// Run the sweep with the paper-scale dataset splits.
pub fn run(
    which: &str,
    formats: &[Precision],
    epochs: usize,
    seed: u64,
) -> Result<Vec<SweepPoint>> {
    run_with(which, formats, epochs, seed, None)
}

/// [`run`] over an explicit stage graph (the `--stages` CLI path).
pub fn run_with(
    which: &str,
    formats: &[Precision],
    epochs: usize,
    seed: u64,
    stages: Option<&str>,
) -> Result<Vec<SweepPoint>> {
    let (train, test) = grid::paper_splits(which);
    run_sized_stages(
        which,
        formats,
        stages,
        epochs,
        grid::PAPER_MLP_EPOCHS,
        seed,
        train,
        test,
    )
}

/// Render as an aligned text table, with the fp32 row as the baseline.
pub fn render(which: &str, points: &[SweepPoint]) -> String {
    let mut out =
        format!("fxp sweep ({which}) — accuracy vs operand width (stage-graph datapath cost)\n");
    out.push_str(&format!(
        "{:<10} {:>6} {:>9} {:>8} {:>10} {:>12} {:>10}\n",
        "precision", "bits", "acc (%)", "DSPs", "ALMs", "reg bits", "DSP ratio"
    ));
    let base_dsps = points
        .iter()
        .find(|p| p.precision == "f32")
        .map(|p| p.dsps as f64);
    for p in points {
        let ratio = base_dsps
            .map(|b| format!("{:.2}x", b / p.dsps.max(1) as f64))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<10} {:>6} {:>9.1} {:>8} {:>10} {:>12} {:>10}\n",
            p.precision, p.width_bits, p.accuracy, p.dsps, p.alms, p.register_bits, ratio
        ));
    }
    out
}

/// Serialise the sweep for downstream plotting.
pub fn to_json(which: &str, points: &[SweepPoint]) -> Json {
    let (m, p, n, _) = dims_for(which).unwrap_or((0, 0, 0, 0));
    Json::obj(vec![
        ("experiment", Json::str("fxp_sweep")),
        ("dataset", Json::str(which)),
        (
            "pipeline",
            Json::obj(vec![
                ("input_dim", Json::num(m as f64)),
                ("intermediate_dim", Json::num(p as f64)),
                ("output_dim", Json::num(n as f64)),
                ("stage", Json::str("rp-ternary + gha-whiten + easi-rotate")),
            ]),
        ),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|pt| {
                        Json::obj(vec![
                            ("precision", Json::str(pt.precision.clone())),
                            ("width_bits", Json::num(pt.width_bits as f64)),
                            ("accuracy", Json::num(pt.accuracy)),
                            ("dsps", Json::num(pt.dsps as f64)),
                            ("alms", Json::num(pt.alms as f64)),
                            ("register_bits", Json::num(pt.register_bits as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::{Arria10Model, HwConfig, NumericFormat};

    #[test]
    fn q4_12_within_two_points_of_f32_on_waveform() {
        // The acceptance criterion: a 16-bit fixed-point pipeline holds
        // waveform accuracy within 2 points of the f32 baseline, while
        // (per hwmodel) costing strictly less on every resource column.
        let pts = run_sized(
            "waveform",
            &[Precision::parse("q4.12").unwrap()],
            3,
            25,
            2018,
            2500,
            600,
        )
        .unwrap();
        assert_eq!(pts.len(), 2);
        let (f32_pt, fx) = (&pts[0], &pts[1]);
        assert_eq!(f32_pt.precision, "f32");
        assert!(
            (f32_pt.accuracy - fx.accuracy).abs() <= 2.0,
            "f32 {:.1} vs q4.12 {:.1}",
            f32_pt.accuracy,
            fx.accuracy
        );
        assert!(f32_pt.accuracy > 60.0, "baseline degenerate: {}", f32_pt.accuracy);
        assert!(fx.dsps < f32_pt.dsps);
        assert!(fx.alms < f32_pt.alms);
        assert!(fx.register_bits < f32_pt.register_bits);
    }

    #[test]
    fn narrow_q1_15_still_learns_waveform() {
        // Q1.15 exercises the prescale + σ-target machinery end to end;
        // it may shed a few points but must stay far above chance (33%).
        let pts = run_sized(
            "waveform",
            &[Precision::parse("q1.15").unwrap()],
            3,
            25,
            2018,
            2500,
            600,
        )
        .unwrap();
        let fx = &pts[1];
        assert_eq!(fx.precision, "q1.15");
        assert!(fx.accuracy > 50.0, "q1.15 accuracy collapsed: {}", fx.accuracy);
    }

    #[test]
    fn ste_orders_no_worse_than_bit_exact_at_8_bits_on_waveform() {
        // The QAT claim on the end-to-end task: at Q4.4 the bit-exact
        // integer update underflows the format (the whitener stays near
        // its random init), while STE trains the same quantized forward
        // datapath with f32 shadow updates. STE must not trail
        // bit-exact, and must keep the task well above chance (33%).
        let pts = run_sized(
            "waveform",
            &[
                Precision::parse("q4.4").unwrap(),
                Precision::parse("q4.4,qat=ste").unwrap(),
            ],
            3,
            25,
            2018,
            2500,
            600,
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        let (exact, ste) = (&pts[1], &pts[2]);
        assert_eq!(exact.precision, "q4.4");
        assert_eq!(ste.precision, "q4.4,qat=ste");
        // Same datapath, same price.
        assert_eq!(exact.dsps, ste.dsps);
        assert_eq!(exact.alms, ste.alms);
        assert!(
            ste.accuracy + 0.5 >= exact.accuracy,
            "STE ({:.1}) must not trail bit-exact ({:.1}) at 8 bits",
            ste.accuracy,
            exact.accuracy
        );
        assert!(ste.accuracy > 65.0, "STE q4.4 collapsed: {}", ste.accuracy);
    }

    #[test]
    fn sweep_costs_monotone_in_width() {
        // No training needed to check the cost columns line up.
        let formats: Vec<Precision> = ["q4.4", "q4.12", "q4.14"]
            .iter()
            .map(|s| Precision::parse(s).unwrap())
            .collect();
        let model = Arria10Model::paper_calibrated();
        let mut last = 0u64;
        for f in &formats {
            let c = model.cost(
                &HwConfig::rp_easi(32, 16, 8).with_format(NumericFormat::from_precision(f)),
            );
            assert!(c.alms >= last);
            last = c.alms;
        }
    }

    #[test]
    fn render_and_json_shape() {
        let pts = vec![
            SweepPoint {
                precision: "f32".into(),
                width_bits: 32,
                accuracy: 80.0,
                dsps: 2212,
                alms: 70031,
                register_bits: 75392,
            },
            SweepPoint {
                precision: "q4.12".into(),
                width_bits: 16,
                accuracy: 79.5,
                dsps: 552,
                alms: 12000,
                register_bits: 37696,
            },
        ];
        let table = render("waveform", &pts);
        assert!(table.contains("q4.12"));
        assert!(table.contains("4.01x") || table.contains("4.00x"));
        let j = to_json("waveform", &pts);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.field("points").unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(parsed.field("dataset").unwrap().as_str().unwrap(), "waveform");
    }
}
