//! Fig. 1 — classification accuracy vs number of (reduced) input
//! features, for four DR algorithms on three datasets:
//!
//! * Fig. 1a MNIST(-like), m = 784: RP/PCA/ICA hold accuracy to ~50-100
//!   features; PCA/ICA degrade latest; bilinear (2-D DCT) competitive.
//! * Fig. 1b HAR(-like), m = 561: ICA and RP outperform; the bilinear
//!   transform collapses (paper: below 60%).
//! * Fig. 1c Ads(-like), m = 1558: accuracy flat down to ~5 features.
//!
//! Datasets are the structural substitutes of DESIGN.md §7, so the
//! acceptance criterion is the *relative shape*, not absolute numbers.

use crate::datasets::{
    ads_like::AdsLikeConfig, har_like::HarLikeConfig, mnist_like::MnistLikeConfig, Dataset,
};
use crate::mlp::{Mlp, MlpConfig};
use crate::pca::dct::{Dct1d, Dct2d};
use crate::pipeline::{DrPipeline, PipelineSpec, RpStage, StageSpec};
use crate::rp::{RandomProjection, RpDistribution};
use anyhow::{bail, Result};

/// The DR algorithms compared in Fig. 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    RandomProjection,
    Pca,
    Ica,
    Bilinear,
}

impl Algorithm {
    pub const ALL: [Algorithm; 4] = [
        Algorithm::RandomProjection,
        Algorithm::Pca,
        Algorithm::Ica,
        Algorithm::Bilinear,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::RandomProjection => "random-projection",
            Algorithm::Pca => "pca",
            Algorithm::Ica => "ica",
            Algorithm::Bilinear => "bilinear",
        }
    }
}

/// One accuracy-vs-dimensions series.
#[derive(Debug, Clone)]
pub struct Series {
    pub algorithm: Algorithm,
    /// (output_dim, test accuracy %) pairs, ascending dims.
    pub points: Vec<(usize, f64)>,
}

/// Dimension grids per dataset (subset of the paper's x-axes, chosen so
/// the full figure regenerates in minutes on CPU).
pub fn dims_for(which: &str, points: usize) -> Result<Vec<usize>> {
    let full: Vec<usize> = match which {
        "mnist" => vec![16, 32, 64, 128, 256],
        "har" => vec![12, 24, 48, 96, 192],
        "ads" => vec![5, 10, 25, 60, 150],
        other => bail!("unknown fig1 dataset '{other}' (mnist|har|ads)"),
    };
    let n = points.clamp(2, full.len());
    // Take an evenly-spaced subset of size `points`.
    let idx = |i: usize| (i * (full.len() - 1)) / (n - 1);
    Ok((0..n).map(|i| full[idx(i)]).collect())
}

fn load(which: &str, seed: u64) -> Result<Dataset> {
    let mut d = match which {
        "mnist" => MnistLikeConfig {
            train: 2000,
            test: 500,
            seed,
            ..Default::default()
        }
        .generate(),
        "har" => HarLikeConfig {
            train: 1500,
            test: 400,
            seed,
        }
        .generate(),
        "ads" => AdsLikeConfig {
            train: 1500,
            test: 400,
            seed,
            ..Default::default()
        }
        .generate(),
        other => bail!("unknown fig1 dataset '{other}'"),
    };
    d.standardize();
    Ok(d)
}

/// Reduce a dataset with one algorithm to `n` dims.
fn reduce(data: &Dataset, alg: Algorithm, n: usize, which: &str, seed: u64) -> Dataset {
    let m = data.input_dim();
    match alg {
        Algorithm::RandomProjection => {
            let rp = RandomProjection::new(m, n, RpDistribution::Ternary, seed);
            Dataset {
                name: format!("{}+rp{n}", data.name),
                train_x: rp.apply_rows(&data.train_x),
                train_y: data.train_y.clone(),
                test_x: rp.apply_rows(&data.test_x),
                test_y: data.test_y.clone(),
                num_classes: data.num_classes,
            }
        }
        Algorithm::Pca => {
            let spec = PipelineSpec {
                input_dim: m,
                rp: None,
                stage: StageSpec::Pca,
                output_dim: n,
                seed,
                precision: crate::fxp::Precision::F32,
            };
            DrPipeline::fit(spec, &data.train_x).transform_dataset(data)
        }
        Algorithm::Ica => {
            // The paper's scalable recipe at figure scale: ternary RP to
            // an intermediate dimension (4n capped at m), then the
            // composed whiten+rotate unit — §IV's proposal applied to
            // large m, with the GHA whitening completion of DESIGN.md.
            let p = (4 * n).min(m);
            let spec = PipelineSpec {
                input_dim: m,
                rp: (p < m).then_some(RpStage {
                    intermediate_dim: p,
                    distribution: RpDistribution::Ternary,
                }),
                stage: StageSpec::Ica {
                    mu_w: 5e-3,
                    mu_rot: 1e-3,
                    epochs: 2,
                },
                output_dim: n,
                seed,
                precision: crate::fxp::Precision::F32,
            };
            DrPipeline::fit(spec, &data.train_x).transform_dataset(data)
        }
        Algorithm::Bilinear => {
            if which == "mnist" {
                // 2-D DCT truncation on the 28×28 grid.
                let d = Dct2d::new(28, n);
                Dataset {
                    name: format!("{}+dct{n}", data.name),
                    train_x: d.transform_rows(&data.train_x),
                    train_y: data.train_y.clone(),
                    test_x: d.transform_rows(&data.test_x),
                    test_y: data.test_y.clone(),
                    num_classes: data.num_classes,
                }
            } else {
                let d = Dct1d::new(m, n);
                Dataset {
                    name: format!("{}+dct{n}", data.name),
                    train_x: d.transform_rows(&data.train_x),
                    train_y: data.train_y.clone(),
                    test_x: d.transform_rows(&data.test_x),
                    test_y: data.test_y.clone(),
                    num_classes: data.num_classes,
                }
            }
        }
    }
}

/// Train the paper's 2×64 classifier on reduced features, return test
/// accuracy in percent.
fn classify(reduced: &Dataset, seed: u64, epochs: usize) -> f64 {
    let mut reduced = reduced.clone();
    reduced.standardize();
    let mut mlp = Mlp::new(MlpConfig {
        epochs,
        seed,
        ..MlpConfig::paper(reduced.input_dim(), reduced.num_classes)
    });
    mlp.train(&reduced.train_x, &reduced.train_y);
    mlp.accuracy(&reduced.test_x, &reduced.test_y) * 100.0
}

/// Run all four algorithm series for one dataset.
pub fn run(which: &str, points: usize, seed: u64) -> Result<Vec<Series>> {
    let data = load(which, seed)?;
    let dims = dims_for(which, points)?;
    let mut out = Vec::new();
    for alg in Algorithm::ALL {
        let mut series = Series {
            algorithm: alg,
            points: Vec::new(),
        };
        for &n in &dims {
            let reduced = reduce(&data, alg, n, which, seed);
            let acc = classify(&reduced, seed, 15);
            series.points.push((n, acc));
        }
        out.push(series);
    }
    Ok(out)
}

/// Render as an aligned text table (dims × algorithms).
pub fn render(which: &str, series: &[Series]) -> String {
    let mut out = format!("Fig. 1 ({which}) — test accuracy (%) vs output dimensions\n");
    out.push_str(&format!("{:<8}", "dims"));
    for s in series {
        out.push_str(&format!("{:>20}", s.algorithm.label()));
    }
    out.push('\n');
    if let Some(first) = series.first() {
        for (i, &(n, _)) in first.points.iter().enumerate() {
            out.push_str(&format!("{:<8}", n));
            for s in series {
                out.push_str(&format!("{:>20.1}", s.points[i].1));
            }
            out.push('\n');
        }
    }
    out
}

/// Reference full-dimensionality accuracy (no DR), for the "does DR
/// hurt?" comparison in reports.
pub fn baseline_accuracy(which: &str, seed: u64) -> Result<f64> {
    let data = load(which, seed)?;
    Ok(classify(&data, seed, 15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_grid_subsets() {
        assert_eq!(dims_for("ads", 2).unwrap(), vec![5, 150]);
        assert_eq!(dims_for("mnist", 5).unwrap(), vec![16, 32, 64, 128, 256]);
        assert_eq!(dims_for("har", 3).unwrap().len(), 3);
        assert!(dims_for("bogus", 3).is_err());
    }

    #[test]
    fn ads_flat_at_tiny_dims() {
        // Fig. 1c's headline: a handful of features suffice. Two points:
        // n=5 and n=150 — RP accuracy at n=5 must stay within 12 points
        // of n=150 and well above chance (50%).
        let series = run("ads", 2, 2018).unwrap();
        let rp = series
            .iter()
            .find(|s| s.algorithm == Algorithm::RandomProjection)
            .unwrap();
        let (small, big) = (rp.points[0].1, rp.points[1].1);
        assert!(small > 78.0, "n=5 accuracy {small}");
        assert!(big - small < 17.0, "n=5 {small} vs n=150 {big}");
        // PCA holds essentially full accuracy at n=5 — the paper's
        // strongest form of the claim.
        let pca = series.iter().find(|s| s.algorithm == Algorithm::Pca).unwrap();
        assert!(pca.points[0].1 > 90.0, "pca n=5 {}", pca.points[0].1);
    }

    #[test]
    fn mnist_algorithms_beat_chance_at_moderate_dims() {
        let series = run("mnist", 2, 2018).unwrap();
        for s in &series {
            let top = s.points.last().unwrap().1;
            assert!(
                top > 30.0,
                "{}: accuracy {top} at max dims (chance = 10%)",
                s.algorithm.label()
            );
        }
    }
}
