//! `dimred bench` — the repo's throughput trajectory, as data.
//!
//! Times samples/second through the DR datapath along three axes:
//!
//! * **precision** — the f32 reference vs the bit-accurate fixed-point
//!   (Q4.12) kernels;
//! * **path** — the training step (ingress → whiten → rotate updates)
//!   vs the forward/inference transform;
//! * **mode** — `per-sample` (one staging vector per call, the shape of
//!   the hot path before the tiled refactor), `tiled` (whole tiles
//!   through reusable scratch workspaces, zero steady-state
//!   allocations) and `multilane` (forward tiles sharded across scoped
//!   threads with a deterministic merge).
//!
//! Every forward measurement first *proves* bit-identity — the tiled
//! and multi-lane raw words must equal the per-sample path exactly, or
//! the bench errors out — so the recorded speedups can never come from
//! silently changed arithmetic.
//!
//! The results are written to `BENCH_throughput.json` under a fixed,
//! validated schema ([`validate`]), so successive PRs can diff
//! throughput the way `fxp-sweep`/`pareto` diff accuracy. CI runs
//! `dimred bench --smoke` (tiny sample counts, same schema) and
//! uploads the JSON as an artifact.
//!
//! Since schema v3 every stage-graph scenario also carries per-stage
//! telemetry `health` rows (saturation rate, raw-word occupancy,
//! headroom), collected on an untimed instrumented pass *after* the
//! throughput measurement so the counters never pollute the timing.
//!
//! Since schema v4 the report also carries a `multi_tenant` scenario
//! family: the serving layer's aggregate throughput (8 concurrent
//! sessions sharded across 2 and 4 workers vs the single-session
//! baseline), per-tenant p50/p99 step latency and the fairness spread —
//! the scalability axis of the paper's pitch, measured through
//! `serve::workload` with every tenant pinned to the same graph shape
//! so the speedup isolates sharding, not precision mix.
//!
//! Since schema v5 every point carries a `simd` flag (whether the
//! vectorized fixed-point dispatch was live for that measurement), and
//! the fixed-point tiled cells come as explicit scalar-vs-simd row
//! pairs: the same kernel timed with the dispatch forced off and in its
//! natural state, preceded by a bit-identity preflight so the recorded
//! `*_simd_over_scalar` speedups can only ever measure speed, never
//! changed arithmetic. With the `simd` cargo feature off both rows of a
//! pair time the scalar path and the speedup sits at ~1.

use crate::experiments::grid;
use crate::fxp::{FxpDrUnit, FxpRp, FxpSpec, FxpUnitConfig, Precision, QuantMode, Scratch};
use crate::linalg::Mat;
use crate::pipeline::unit::{DrUnit, DrUnitConfig};
use crate::rp::{RandomProjection, RpDistribution};
use crate::serve::workload::{self, ArrivalPattern, ServeOptions};
use crate::stage::spec::parse_stage_list;
use crate::stage::GraphSpec;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::time::Instant;

/// One timed point: a (path, precision, mode) cell of the grid.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    /// `"train"` or `"forward"`.
    pub path: &'static str,
    /// `"f32"` or the fixed-point format label.
    pub precision: String,
    /// `"per-sample"`, `"tiled"` or `"multilane"`.
    pub mode: &'static str,
    /// Lanes used (1 except for multilane).
    pub lanes: usize,
    /// Whether the vectorized fixed-point dispatch was live for this
    /// measurement (always false for f32 rows and for the forced-scalar
    /// half of a scalar-vs-simd pair).
    pub simd: bool,
    /// Samples processed per measured repetition.
    pub samples: usize,
    /// Best-of-reps throughput.
    pub samples_per_s: f64,
}

/// One stage-graph scenario's forward throughput — the
/// scenario-diversity axis: non-paper cascades (`rp→pca`,
/// `dct→whiten→rot`, whiten-only fixed point) benched through the same
/// harness with zero new plumbing.
#[derive(Debug, Clone)]
pub struct ScenarioPoint {
    /// Canonical stage list (round-trips through the `--stages` parser).
    pub stages: String,
    /// Precision label the graph ran at.
    pub precision: String,
    /// Whole-tile forward throughput.
    pub samples_per_s: f64,
    /// Per-stage numeric health, collected on one *untimed* pass with
    /// telemetry enabled after the throughput measurement (so the
    /// instrumentation cannot pollute the timed numbers).
    pub health: Vec<StageHealth>,
}

/// One stage's telemetry row in a bench scenario: the saturation /
/// occupancy signal joined into the throughput trajectory.
#[derive(Debug, Clone)]
pub struct StageHealth {
    pub stage: String,
    /// Saturation events per forward sample (0 for f32 stages).
    pub sat_per_sample: f64,
    /// Highest occupied raw-word magnitude bit-length (0 for f32).
    pub max_bits: u32,
    /// Unused top magnitude bits vs the stage format (None for f32).
    pub headroom_bits: Option<u32>,
}

/// All points for one dataset configuration, plus derived speedups.
#[derive(Debug, Clone)]
pub struct BenchConfigResult {
    pub dataset: String,
    pub m: usize,
    pub p: usize,
    pub n: usize,
    pub samples: usize,
    pub points: Vec<BenchPoint>,
    /// (label, ratio) pairs, e.g. `train_fxp_tiled_over_per_sample`.
    pub speedups: Vec<(String, f64)>,
    /// Stage-graph scenarios (forward path, whole-tile).
    pub scenarios: Vec<ScenarioPoint>,
}

/// One multi-tenant serving measurement: aggregate samples/s for
/// `tenants` concurrent sessions on `shards` workers, vs the
/// single-session baseline row (tenants=1, shards=1).
#[derive(Debug, Clone)]
pub struct MultiTenantPoint {
    pub tenants: usize,
    pub shards: usize,
    /// Rows per batch.
    pub batch: usize,
    pub batches_per_tenant: usize,
    /// Precision label every tenant ran at, or `"mixed"` for the
    /// cycling f32/q4.12 preset rows.
    pub precision: String,
    /// Whether the shards ran the two-slot stage/commit pipeline.
    pub pipelined: bool,
    pub aggregate_samples_per_s: f64,
    /// Worst per-tenant median step latency.
    pub p50_ns: Option<f64>,
    /// Worst per-tenant p99 step latency.
    pub p99_ns: Option<f64>,
    /// Slowest / fastest tenant completion (1.0 = perfectly fair).
    pub fairness_spread: Option<f64>,
    /// Aggregate throughput over the single-session baseline row.
    pub speedup_over_single: f64,
    /// Pipelined aggregate over its serial twin (same workload, serial
    /// scheduler), present only on pipelined rows — and only after the
    /// bit-identity preflight proved the two schedulers produce
    /// word-for-word identical trainer state.
    pub pipelined_over_serial: Option<f64>,
}

/// Everything one bench run produces: the per-dataset kernel grid plus
/// the multi-tenant serving family.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub configs: Vec<BenchConfigResult>,
    pub multi_tenant: Vec<MultiTenantPoint>,
}

/// Knobs for one bench run.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Dataset names (waveform | har).
    pub datasets: Vec<String>,
    /// Rows per tile fed to the tiled/multilane paths.
    pub tile: usize,
    /// Lanes for the multilane forward path.
    pub lanes: usize,
    /// Tiny sample counts for CI smoke runs (same schema).
    pub smoke: bool,
    pub seed: u64,
}

impl Default for BenchOptions {
    fn default() -> Self {
        Self {
            datasets: vec!["waveform".into(), "har".into()],
            tile: 256,
            lanes: 4,
            smoke: false,
            seed: 2018,
        }
    }
}

/// The fixed-point format the bench prices the quantized datapath at —
/// the paper's 16-bit deployment width.
fn bench_spec() -> FxpSpec {
    FxpSpec::q(4, 12)
}

/// Best-of-`reps` throughput of `f`, which processes `samples` samples
/// per call.
fn time_samples(reps: usize, samples: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    samples as f64 / best.max(1e-12)
}

/// Contiguous `(start_row, rows_in_tile)` ranges covering `rows` rows —
/// every tiled measurement chunks by the same `--tile` knob so the
/// recorded tile size is the tile size actually run.
fn tile_ranges(rows: usize, tile: usize) -> impl Iterator<Item = (usize, usize)> {
    let tile = tile.max(1);
    (0..rows)
        .step_by(tile)
        .map(move |start| (start, tile.min(rows - start)))
}

/// Per-sample fixed-point ingress — deliberately the *allocating* shape
/// of the pre-tile hot path (one staging vector per call), kept here
/// only as the baseline the tiled kernels are measured against. Its
/// arithmetic must match the shared tile ingress
/// ([`crate::fxp::kernels::ingress_tile`], which the trainer and the
/// tiled measurements below use); the bench asserts raw-word equality
/// between the two before any timing runs.
fn ingress_per_sample(
    rp: &FxpRp,
    entry: &FxpSpec,
    wspec: &FxpSpec,
    prescale: f32,
    row: &[f32],
) -> Vec<i32> {
    let xq: Vec<i32> = row.iter().map(|&v| entry.quantize(v * prescale)).collect();
    wspec.requantize_vec_from(&rp.apply_raw(&xq), entry)
}

/// The shared tile ingress (same definition the trainer runs), bound to
/// the bench's RP front end.
fn ingress_tile(
    rp: &FxpRp,
    entry: &FxpSpec,
    wspec: &FxpSpec,
    prescale: f32,
    x: &[f32],
    rows: usize,
    scratch: &mut Scratch,
) {
    crate::fxp::kernels::ingress_tile(Some(rp), entry, wspec, prescale, x, rows, scratch);
}

fn build_fxp_unit(p: usize, n: usize, seed: u64) -> FxpDrUnit {
    let spec = bench_spec();
    FxpDrUnit::new(FxpUnitConfig {
        input_dim: p,
        output_dim: n,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: 0,
        seed,
        whiten_spec: spec,
        rot_spec: spec,
        quant: QuantMode::BitExact,
    })
}

fn build_f32_unit(p: usize, n: usize, seed: u64) -> DrUnit {
    DrUnit::new(DrUnitConfig {
        input_dim: p,
        output_dim: n,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: 0,
        seed,
    })
}

/// Run the bench over every requested dataset configuration, then the
/// multi-tenant serving family.
pub fn run(opts: &BenchOptions) -> Result<BenchReport> {
    ensure!(opts.tile >= 1, "tile must be >= 1");
    ensure!(opts.lanes >= 1, "lanes must be >= 1");
    ensure!(!opts.datasets.is_empty(), "no datasets selected");
    let reps = if opts.smoke { 2 } else { 5 };
    let mut out = Vec::new();
    for name in &opts.datasets {
        let (m, p, n, _) = grid::dims_for(name)?;
        // Throughput depends on dims, not content; still use the real
        // generators so the bench exercises exactly the data the
        // accuracy experiments stream.
        let (train, test) = if opts.smoke { (256, 8) } else { (2048, 8) };
        let data = grid::load(name, opts.seed, train, test)?;
        let x = &data.train_x;
        let rows = x.rows_count();
        let samples = rows;
        let fspec = bench_spec();
        let precision_label = Precision::Fixed(crate::fxp::PrecisionPlan::uniform(fspec)).label();

        let rp = RandomProjection::new(m, p, RpDistribution::Ternary, opts.seed).unit_variance();
        let frp = FxpRp::from_rp(&rp, fspec);
        let plan = crate::fxp::PrecisionPlan::uniform(fspec);
        let entry = plan.rp;
        let prescale = plan.entry_prescale(true, &plan.whiten);
        let mut points = Vec::new();

        // ------------------------------------------------- train, f32
        let mut unit = build_f32_unit(p, n, opts.seed);
        let t_f32_per_sample = time_samples(reps, samples, || {
            for i in 0..rows {
                let proj = rp.apply(x.row(i));
                unit.step(&proj);
            }
        });
        points.push(BenchPoint {
            path: "train",
            precision: "f32".into(),
            mode: "per-sample",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: t_f32_per_sample,
        });
        let mut unit = build_f32_unit(p, n, opts.seed);
        let mut staged = Mat::zeros(opts.tile.min(rows).max(1), p);
        let t_f32_tiled = time_samples(reps, samples, || {
            for (start, r) in tile_ranges(rows, opts.tile) {
                if staged.shape() != (r, p) {
                    staged = Mat::zeros(r, p);
                }
                for local in 0..r {
                    rp.apply_into(x.row(start + local), staged.row_mut(local));
                }
                unit.step_rows(&staged);
            }
        });
        points.push(BenchPoint {
            path: "train",
            precision: "f32".into(),
            mode: "tiled",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: t_f32_tiled,
        });

        // --------------------------------- simd bit-identity preflight
        // Train a fresh unit over the whole tile and transform it back,
        // once with the vectorized dispatch forced off and once in its
        // natural state. The raw words must match exactly *before* any
        // scalar-vs-simd pair is timed, so the recorded speedups can
        // only ever measure speed, never changed arithmetic. With the
        // `simd` feature off both runs take the scalar path and the
        // check is trivially true.
        let train_and_forward_words = |force_scalar: bool| -> Vec<i32> {
            crate::fxp::simd::set_force_scalar(force_scalar);
            let mut u = build_fxp_unit(p, n, opts.seed);
            let ws = u.config.whiten_spec;
            let mut s = Scratch::new();
            ingress_tile(&frp, &entry, &ws, prescale, x.as_slice(), rows, &mut s);
            u.step_tile_raw(&s.stage, rows);
            let stage = s.stage.clone();
            let mut out = Vec::new();
            u.transform_tile_raw(&stage, rows, &mut s, &mut out);
            crate::fxp::simd::set_force_scalar(false);
            out
        };
        ensure!(
            train_and_forward_words(true) == train_and_forward_words(false),
            "vectorized dispatch diverged from the scalar kernels ({name})"
        );

        // ------------------------------------------------- train, fxp
        let mut unit = build_fxp_unit(p, n, opts.seed);
        let wspec = unit.config.whiten_spec;
        let t_fxp_per_sample = time_samples(reps, samples, || {
            for i in 0..rows {
                let staged = ingress_per_sample(&frp, &entry, &wspec, prescale, x.row(i));
                unit.step_raw(&staged);
            }
        });
        points.push(BenchPoint {
            path: "train",
            precision: precision_label.clone(),
            mode: "per-sample",
            lanes: 1,
            simd: crate::fxp::simd::enabled(),
            samples,
            samples_per_s: t_fxp_per_sample,
        });
        // Scalar half of the train scalar-vs-simd pair: the same tiled
        // kernel with the vectorized dispatch forced off.
        let mut unit = build_fxp_unit(p, n, opts.seed);
        let mut scratch = Scratch::new();
        crate::fxp::simd::set_force_scalar(true);
        let t_fxp_tiled_scalar = time_samples(reps, samples, || {
            for tile_rows in x.as_slice().chunks(opts.tile * m) {
                let r = tile_rows.len() / m;
                ingress_tile(&frp, &entry, &wspec, prescale, tile_rows, r, &mut scratch);
                unit.step_tile_raw(&scratch.stage, r);
            }
        });
        crate::fxp::simd::set_force_scalar(false);
        points.push(BenchPoint {
            path: "train",
            precision: precision_label.clone(),
            mode: "tiled",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: t_fxp_tiled_scalar,
        });
        let mut unit = build_fxp_unit(p, n, opts.seed);
        let t_fxp_tiled = time_samples(reps, samples, || {
            // Tile-at-a-time, like the trainer: whole batches through
            // reusable workspaces.
            for tile_rows in x.as_slice().chunks(opts.tile * m) {
                let r = tile_rows.len() / m;
                ingress_tile(&frp, &entry, &wspec, prescale, tile_rows, r, &mut scratch);
                unit.step_tile_raw(&scratch.stage, r);
            }
        });
        points.push(BenchPoint {
            path: "train",
            precision: precision_label.clone(),
            mode: "tiled",
            lanes: 1,
            simd: crate::fxp::simd::enabled(),
            samples,
            samples_per_s: t_fxp_tiled,
        });

        // ----------------------------------------------- forward, f32
        let unit = {
            let mut u = build_f32_unit(p, n, opts.seed);
            u.step_rows(&rp.apply_rows(x));
            u
        };
        let f_f32_per_sample = time_samples(reps, samples, || {
            for i in 0..rows {
                let proj = rp.apply(x.row(i));
                std::hint::black_box(unit.transform(&proj));
            }
        });
        points.push(BenchPoint {
            path: "forward",
            precision: "f32".into(),
            mode: "per-sample",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: f_f32_per_sample,
        });
        let eff = unit.effective_matrix();
        let tile0 = opts.tile.min(rows).max(1);
        let mut staged = Mat::zeros(tile0, p);
        let mut out_f32 = Mat::zeros(tile0, n);
        let f_f32_tiled = time_samples(reps, samples, || {
            for (start, r) in tile_ranges(rows, opts.tile) {
                if staged.shape() != (r, p) {
                    staged = Mat::zeros(r, p);
                    out_f32 = Mat::zeros(r, n);
                }
                for local in 0..r {
                    rp.apply_into(x.row(start + local), staged.row_mut(local));
                }
                eff.apply_rows_into(&staged, &mut out_f32);
                std::hint::black_box(&out_f32);
            }
        });
        points.push(BenchPoint {
            path: "forward",
            precision: "f32".into(),
            mode: "tiled",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: f_f32_tiled,
        });

        // ----------------------------------------------- forward, fxp
        let unit = {
            let mut u = build_fxp_unit(p, n, opts.seed);
            let mut s = Scratch::new();
            ingress_tile(&frp, &entry, &wspec, prescale, x.as_slice(), rows, &mut s);
            u.step_tile_raw(&s.stage, rows);
            u
        };
        let mut scratch = Scratch::new();
        ingress_tile(&frp, &entry, &wspec, prescale, x.as_slice(), rows, &mut scratch);
        let stage_tile = scratch.stage.clone();

        // Bit-identity proof before timing: per-sample raw words are
        // the reference; the shared tile ingress and the tiled /
        // multi-lane forwards must all match exactly.
        let mut reference: Vec<i32> = Vec::with_capacity(rows * n);
        for i in 0..rows {
            let staged = ingress_per_sample(&frp, &entry, &wspec, prescale, x.row(i));
            ensure!(
                staged[..] == stage_tile[i * p..(i + 1) * p],
                "tile ingress diverged from the per-sample ingress ({name})"
            );
            reference.extend(unit.transform_raw(&staged));
        }
        let mut tiled_out = Vec::new();
        let mut s2 = Scratch::new();
        unit.transform_tile_raw(&stage_tile, rows, &mut s2, &mut tiled_out);
        ensure!(
            tiled_out == reference,
            "tiled forward diverged from the per-sample path ({name})"
        );
        let mut lane_out = Vec::new();
        unit.transform_tile_raw_multilane(&stage_tile, rows, opts.lanes, &mut lane_out);
        ensure!(
            lane_out == reference,
            "multi-lane forward diverged from the per-sample path ({name})"
        );

        let f_fxp_per_sample = time_samples(reps, samples, || {
            for i in 0..rows {
                let staged = ingress_per_sample(&frp, &entry, &wspec, prescale, x.row(i));
                std::hint::black_box(unit.transform_raw(&staged));
            }
        });
        points.push(BenchPoint {
            path: "forward",
            precision: precision_label.clone(),
            mode: "per-sample",
            lanes: 1,
            simd: crate::fxp::simd::enabled(),
            samples,
            samples_per_s: f_fxp_per_sample,
        });
        // Scalar half of the forward scalar-vs-simd pair.
        let mut out_raw = Vec::new();
        crate::fxp::simd::set_force_scalar(true);
        let f_fxp_tiled_scalar = time_samples(reps, samples, || {
            for (start, r) in tile_ranges(rows, opts.tile) {
                let xs = &x.as_slice()[start * m..(start + r) * m];
                ingress_tile(&frp, &entry, &wspec, prescale, xs, r, &mut scratch);
                unit.transform_tile_raw(&scratch.stage, r, &mut s2, &mut out_raw);
                std::hint::black_box(&out_raw);
            }
        });
        crate::fxp::simd::set_force_scalar(false);
        points.push(BenchPoint {
            path: "forward",
            precision: precision_label.clone(),
            mode: "tiled",
            lanes: 1,
            simd: false,
            samples,
            samples_per_s: f_fxp_tiled_scalar,
        });
        let f_fxp_tiled = time_samples(reps, samples, || {
            for (start, r) in tile_ranges(rows, opts.tile) {
                let xs = &x.as_slice()[start * m..(start + r) * m];
                ingress_tile(&frp, &entry, &wspec, prescale, xs, r, &mut scratch);
                unit.transform_tile_raw(&scratch.stage, r, &mut s2, &mut out_raw);
                std::hint::black_box(&out_raw);
            }
        });
        points.push(BenchPoint {
            path: "forward",
            precision: precision_label.clone(),
            mode: "tiled",
            lanes: 1,
            simd: crate::fxp::simd::enabled(),
            samples,
            samples_per_s: f_fxp_tiled,
        });
        let f_fxp_multilane = time_samples(reps, samples, || {
            for (start, r) in tile_ranges(rows, opts.tile) {
                let xs = &x.as_slice()[start * m..(start + r) * m];
                ingress_tile(&frp, &entry, &wspec, prescale, xs, r, &mut scratch);
                unit.transform_tile_raw_multilane(&scratch.stage, r, opts.lanes, &mut out_raw);
                std::hint::black_box(&out_raw);
            }
        });
        points.push(BenchPoint {
            path: "forward",
            precision: precision_label.clone(),
            mode: "multilane",
            lanes: opts.lanes,
            simd: crate::fxp::simd::enabled(),
            samples,
            samples_per_s: f_fxp_multilane,
        });

        let speedups = vec![
            (
                "train_f32_tiled_over_per_sample".to_string(),
                t_f32_tiled / t_f32_per_sample.max(1e-12),
            ),
            (
                "train_fxp_tiled_over_per_sample".to_string(),
                t_fxp_tiled / t_fxp_per_sample.max(1e-12),
            ),
            (
                "forward_fxp_tiled_over_per_sample".to_string(),
                f_fxp_tiled / f_fxp_per_sample.max(1e-12),
            ),
            (
                "forward_fxp_multilane_over_per_sample".to_string(),
                f_fxp_multilane / f_fxp_per_sample.max(1e-12),
            ),
            (
                "train_fxp_tiled_simd_over_scalar".to_string(),
                t_fxp_tiled / t_fxp_tiled_scalar.max(1e-12),
            ),
            (
                "forward_fxp_tiled_simd_over_scalar".to_string(),
                f_fxp_tiled / f_fxp_tiled_scalar.max(1e-12),
            ),
        ];
        // ------------------------------------------- graph scenarios
        // Non-paper cascades through the stage-graph datapath: fit
        // briefly, then time the whole-tile forward. These rows are the
        // scenario-diversity trajectory (zero plumbing per new graph:
        // a stage list + a precision string).
        let scenario_specs = [
            (format!("rp:ternary/{p},pca"), "f32"),
            (format!("dct/{p},whiten:gha,rot:easi"), "f32"),
            ("whiten:gha".to_string(), "q4.12"),
        ];
        let mut scenarios = Vec::new();
        for (stages, prec) in scenario_specs {
            let gspec = GraphSpec {
                input_dim: m,
                output_dim: n,
                stages: parse_stage_list(&stages)?,
                seed: opts.seed,
                precision: Precision::parse(prec)?,
                mu_w: 5e-3,
                mu_rot: 1e-3,
                rot_warmup: Some(0),
                epochs: 1,
            };
            let mut graph = gspec.build(Some(rows))?;
            graph.fit(x, 1);
            let tput = time_samples(reps, samples, || {
                std::hint::black_box(graph.transform_rows(x));
            });
            // Health join: instrument *after* timing, run one untimed
            // pass, and read the per-stage saturation/occupancy signal.
            graph.enable_telemetry();
            graph.transform_rows(x);
            let snap = graph
                .telemetry_snapshot()
                .context("telemetry enabled but no snapshot")?;
            let health = snap
                .all()
                .map(|s| StageHealth {
                    stage: s.name.clone(),
                    sat_per_sample: s.sat_per_sample(),
                    max_bits: s.max_bits(),
                    headroom_bits: s.headroom_bits(),
                })
                .collect();
            scenarios.push(ScenarioPoint {
                stages: gspec.stages_label(),
                precision: prec.to_string(),
                samples_per_s: tput,
                health,
            });
        }

        out.push(BenchConfigResult {
            dataset: name.clone(),
            m,
            p,
            n,
            samples,
            points,
            speedups,
            scenarios,
        });
    }
    let multi_tenant = run_multi_tenant(opts)?;
    Ok(BenchReport {
        configs: out,
        multi_tenant,
    })
}

/// Worst per-tenant latency: the row a latency SLO would look at.
fn worst_tenant_ns(
    report: &crate::serve::workload::ServeReport,
    f: fn(&crate::serve::workload::TenantReport) -> Option<f64>,
) -> Option<f64> {
    report
        .tenants
        .iter()
        .filter_map(f)
        .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
}

/// The multi-tenant serving family: a single-session baseline row
/// (tenants=1, shards=1) followed by 8 sessions on 2 and 4 shards,
/// every tenant pinned to the same f32 rp-easi graph so the measured
/// speedup isolates sharding — then a serial-vs-pipelined pair on the
/// mixed f32/q4.12 preset at 8 tenants on 2 shards (the pipeline's
/// target case: per-tenant same-plan batches fuse into mega-tiles while
/// staging overlaps commits). The pipelined row's `pipelined_over_serial`
/// is recorded only after [`workload::pipeline_identity_check`] proves
/// both schedulers produce word-for-word identical trainer state — a
/// speedup from changed arithmetic is not a speedup.
fn run_multi_tenant(opts: &BenchOptions) -> Result<Vec<MultiTenantPoint>> {
    let batches_per_tenant = if opts.smoke { 32 } else { 128 };
    let grid = [(1usize, 1usize), (8, 2), (8, 4)];
    let mut rows = Vec::with_capacity(grid.len() + 2);
    let mut baseline: Option<f64> = None;
    for (tenants, shards) in grid {
        let sopts = ServeOptions {
            tenants,
            shards,
            batch: 256,
            batches_per_tenant,
            arrival: ArrivalPattern::Uniform,
            stages: None,
            precision: Some("f32".into()),
            telemetry: false,
            seed: opts.seed,
            ..ServeOptions::default()
        };
        let report = workload::run(&sopts)?;
        let agg = report.aggregate_samples_per_s;
        let base = *baseline.get_or_insert(agg);
        rows.push(MultiTenantPoint {
            tenants,
            shards,
            batch: sopts.batch,
            batches_per_tenant,
            precision: "f32".into(),
            pipelined: false,
            aggregate_samples_per_s: agg,
            p50_ns: worst_tenant_ns(&report, |t| t.p50_ns),
            p99_ns: worst_tenant_ns(&report, |t| t.p99_ns),
            fairness_spread: report.fairness_spread,
            speedup_over_single: agg / base.max(1e-12),
            pipelined_over_serial: None,
        });
    }
    let base = baseline.expect("grid is non-empty").max(1e-12);

    // The serial-vs-pipelined pair on the mixed preset.
    let mixed = |pipeline: bool| ServeOptions {
        tenants: 8,
        shards: 2,
        batch: 256,
        batches_per_tenant,
        arrival: ArrivalPattern::Uniform,
        stages: None,
        precision: None,
        telemetry: false,
        pipeline,
        seed: opts.seed,
        ..ServeOptions::default()
    };
    ensure!(
        workload::pipeline_identity_check(&mixed(true))?,
        "pipelined scheduler diverged from the serial oracle; refusing to record a speedup"
    );
    let serial = workload::run(&mixed(false))?;
    let piped = workload::run(&mixed(true))?;
    for (report, pipelined) in [(&serial, false), (&piped, true)] {
        let agg = report.aggregate_samples_per_s;
        rows.push(MultiTenantPoint {
            tenants: 8,
            shards: 2,
            batch: 256,
            batches_per_tenant,
            precision: "mixed".into(),
            pipelined,
            aggregate_samples_per_s: agg,
            p50_ns: worst_tenant_ns(report, |t| t.p50_ns),
            p99_ns: worst_tenant_ns(report, |t| t.p99_ns),
            fairness_spread: report.fairness_spread,
            speedup_over_single: agg / base,
            pipelined_over_serial: pipelined
                .then(|| agg / serial.aggregate_samples_per_s.max(1e-12)),
        });
    }
    Ok(rows)
}

/// Aligned text report.
pub fn render(opts: &BenchOptions, report: &BenchReport) -> String {
    let mut s = format!(
        "dimred bench — samples/s (tile={}, lanes={}{})\n",
        opts.tile,
        opts.lanes,
        if opts.smoke { ", smoke" } else { "" }
    );
    for cfg in &report.configs {
        s.push_str(&format!(
            "\n[{} m={} p={} n={} samples={}]\n",
            cfg.dataset, cfg.m, cfg.p, cfg.n, cfg.samples
        ));
        s.push_str(&format!(
            "{:<9} {:<10} {:<11} {:>6} {:>5} {:>14}\n",
            "path", "precision", "mode", "lanes", "simd", "samples/s"
        ));
        for pt in &cfg.points {
            s.push_str(&format!(
                "{:<9} {:<10} {:<11} {:>6} {:>5} {:>14.0}\n",
                pt.path,
                pt.precision,
                pt.mode,
                pt.lanes,
                if pt.simd { "yes" } else { "-" },
                pt.samples_per_s
            ));
        }
        for (label, ratio) in &cfg.speedups {
            s.push_str(&format!("  {label}: {ratio:.2}x\n"));
        }
        for sc in &cfg.scenarios {
            s.push_str(&format!(
                "  scenario {:<40} {:<10} {:>14.0}\n",
                sc.stages, sc.precision, sc.samples_per_s
            ));
            for h in &sc.health {
                let headroom = h
                    .headroom_bits
                    .map(|b| format!("{b}b"))
                    .unwrap_or_else(|| "-".into());
                s.push_str(&format!(
                    "    health {:<14} sat/smp={:<8.3} max_bits={:<3} headroom={}\n",
                    h.stage, h.sat_per_sample, h.max_bits, headroom
                ));
            }
        }
    }
    if !report.multi_tenant.is_empty() {
        s.push_str("\n[multi-tenant serving — uniform arrival]\n");
        s.push_str(&format!(
            "{:>7} {:>6} {:>6} {:>8} {:>9} {:>5} {:>14} {:>10} {:>10} {:>8} {:>8} {:>9}\n",
            "tenants",
            "shards",
            "batch",
            "batches",
            "precision",
            "pipe",
            "agg smp/s",
            "p50",
            "p99",
            "spread",
            "speedup",
            "pipe/ser"
        ));
        let fmt_ns = |v: Option<f64>| {
            v.map(|ns| crate::util::bench::fmt_duration(std::time::Duration::from_nanos(ns as u64)))
                .unwrap_or_else(|| "-".into())
        };
        for mt in &report.multi_tenant {
            s.push_str(&format!(
                "{:>7} {:>6} {:>6} {:>8} {:>9} {:>5} {:>14.0} {:>10} {:>10} {:>8} {:>7.2}x {:>9}\n",
                mt.tenants,
                mt.shards,
                mt.batch,
                mt.batches_per_tenant,
                mt.precision,
                if mt.pipelined { "yes" } else { "-" },
                mt.aggregate_samples_per_s,
                fmt_ns(mt.p50_ns),
                fmt_ns(mt.p99_ns),
                mt.fairness_spread
                    .map(|f| format!("{f:.2}x"))
                    .unwrap_or_else(|| "-".into()),
                mt.speedup_over_single,
                mt.pipelined_over_serial
                    .map(|r| format!("{r:.2}x"))
                    .unwrap_or_else(|| "-".into())
            ));
        }
    }
    s
}

/// Serialise one run under the golden schema (see [`validate`]).
pub fn to_json(opts: &BenchOptions, report: &BenchReport) -> Json {
    Json::obj(vec![
        ("experiment", Json::str("bench_throughput")),
        // v2: per-config stage-graph `scenarios` rows joined the grid.
        // v3: each scenario carries per-stage telemetry `health` rows
        //     (saturation rate, raw-word occupancy, headroom).
        // v4: top-level `multi_tenant` serving family (aggregate
        //     throughput vs the single-session baseline, worst-tenant
        //     p50/p99, fairness spread).
        // v5: per-point `simd` flag plus scalar-vs-simd row pairs for
        //     the fixed-point tiled cells (and the matching
        //     `*_simd_over_scalar` speedups).
        // v6: multi_tenant rows carry `precision` and `pipelined`, and
        //     the family gains a serial-vs-pipelined pair on the mixed
        //     preset with the `pipelined_over_serial` speedup (gated on
        //     the scheduler bit-identity preflight).
        ("schema_version", Json::num(6.0)),
        ("smoke", Json::Bool(opts.smoke)),
        ("tile", Json::num(opts.tile as f64)),
        ("lanes", Json::num(opts.lanes as f64)),
        ("seed", Json::num(opts.seed as f64)),
        (
            "configs",
            Json::Arr(
                report
                    .configs
                    .iter()
                    .map(|cfg| {
                        Json::obj(vec![
                            ("dataset", Json::str(cfg.dataset.clone())),
                            ("m", Json::num(cfg.m as f64)),
                            ("p", Json::num(cfg.p as f64)),
                            ("n", Json::num(cfg.n as f64)),
                            ("samples", Json::num(cfg.samples as f64)),
                            (
                                "points",
                                Json::Arr(
                                    cfg.points
                                        .iter()
                                        .map(|pt| {
                                            Json::obj(vec![
                                                ("path", Json::str(pt.path)),
                                                (
                                                    "precision",
                                                    Json::str(pt.precision.clone()),
                                                ),
                                                ("mode", Json::str(pt.mode)),
                                                ("lanes", Json::num(pt.lanes as f64)),
                                                ("simd", Json::Bool(pt.simd)),
                                                ("samples", Json::num(pt.samples as f64)),
                                                (
                                                    "samples_per_s",
                                                    Json::num(pt.samples_per_s),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                            (
                                "speedups",
                                Json::Obj(
                                    cfg.speedups
                                        .iter()
                                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                                        .collect(),
                                ),
                            ),
                            (
                                "scenarios",
                                Json::Arr(
                                    cfg.scenarios
                                        .iter()
                                        .map(|sc| {
                                            Json::obj(vec![
                                                ("stages", Json::str(sc.stages.clone())),
                                                (
                                                    "precision",
                                                    Json::str(sc.precision.clone()),
                                                ),
                                                (
                                                    "samples_per_s",
                                                    Json::num(sc.samples_per_s),
                                                ),
                                                (
                                                    "health",
                                                    Json::Arr(
                                                        sc.health
                                                            .iter()
                                                            .map(|h| {
                                                                Json::obj(vec![
                                                                    (
                                                                        "stage",
                                                                        Json::str(
                                                                            h.stage.clone(),
                                                                        ),
                                                                    ),
                                                                    (
                                                                        "sat_per_sample",
                                                                        Json::num(
                                                                            h.sat_per_sample,
                                                                        ),
                                                                    ),
                                                                    (
                                                                        "max_bits",
                                                                        Json::num(
                                                                            h.max_bits as f64,
                                                                        ),
                                                                    ),
                                                                    (
                                                                        "headroom_bits",
                                                                        h.headroom_bits
                                                                            .map(|b| {
                                                                                Json::num(
                                                                                    b as f64,
                                                                                )
                                                                            })
                                                                            .unwrap_or(
                                                                                Json::Null,
                                                                            ),
                                                                    ),
                                                                ])
                                                            })
                                                            .collect(),
                                                    ),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "multi_tenant",
            Json::Arr(
                report
                    .multi_tenant
                    .iter()
                    .map(|mt| {
                        Json::obj(vec![
                            ("tenants", Json::num(mt.tenants as f64)),
                            ("shards", Json::num(mt.shards as f64)),
                            ("batch", Json::num(mt.batch as f64)),
                            (
                                "batches_per_tenant",
                                Json::num(mt.batches_per_tenant as f64),
                            ),
                            ("precision", Json::str(mt.precision.clone())),
                            ("pipelined", Json::Bool(mt.pipelined)),
                            (
                                "aggregate_samples_per_s",
                                Json::num(mt.aggregate_samples_per_s),
                            ),
                            ("p50_ns", mt.p50_ns.map(Json::num).unwrap_or(Json::Null)),
                            ("p99_ns", mt.p99_ns.map(Json::num).unwrap_or(Json::Null)),
                            (
                                "fairness_spread",
                                mt.fairness_spread.map(Json::num).unwrap_or(Json::Null),
                            ),
                            (
                                "speedup_over_single",
                                Json::num(mt.speedup_over_single),
                            ),
                            (
                                "pipelined_over_serial",
                                mt.pipelined_over_serial
                                    .map(Json::num)
                                    .unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Golden-schema check for `BENCH_throughput.json` — run by the CLI on
/// its own output and by CI on the uploaded artifact, so a drifting
/// writer can never silently break the cross-PR trajectory.
pub fn validate(v: &Json) -> Result<()> {
    ensure!(
        v.field("experiment")?.as_str()? == "bench_throughput",
        "wrong experiment tag"
    );
    ensure!(
        v.field("schema_version")?.as_usize()? == 6,
        "unknown schema version"
    );
    v.field("smoke")?.as_bool().context("smoke flag")?;
    v.field("tile")?.as_usize().context("tile")?;
    v.field("lanes")?.as_usize().context("lanes")?;
    let configs = v.field("configs")?.as_arr()?;
    ensure!(!configs.is_empty(), "configs must be non-empty");
    for cfg in configs {
        cfg.field("dataset")?.as_str()?;
        for key in ["m", "p", "n", "samples"] {
            cfg.field(key)?.as_usize().with_context(|| key.to_string())?;
        }
        let points = cfg.field("points")?.as_arr()?;
        ensure!(!points.is_empty(), "points must be non-empty");
        for pt in points {
            let path = pt.field("path")?.as_str()?;
            ensure!(
                path == "train" || path == "forward",
                "unknown path '{path}'"
            );
            pt.field("precision")?.as_str()?;
            let mode = pt.field("mode")?.as_str()?;
            ensure!(
                mode == "per-sample" || mode == "tiled" || mode == "multilane",
                "unknown mode '{mode}'"
            );
            ensure!(pt.field("lanes")?.as_usize()? >= 1, "lanes must be >= 1");
            pt.field("simd")?.as_bool().context("simd flag")?;
            pt.field("samples")?.as_usize()?;
            let tput = pt.field("samples_per_s")?.as_f64()?;
            ensure!(
                tput.is_finite() && tput > 0.0,
                "samples_per_s must be positive, got {tput}"
            );
        }
        cfg.field("speedups")?.as_obj()?;
        let scenarios = cfg.field("scenarios")?.as_arr()?;
        ensure!(!scenarios.is_empty(), "scenarios must be non-empty");
        for sc in scenarios {
            sc.field("stages")?.as_str()?;
            sc.field("precision")?.as_str()?;
            let tput = sc.field("samples_per_s")?.as_f64()?;
            ensure!(
                tput.is_finite() && tput > 0.0,
                "scenario samples_per_s must be positive, got {tput}"
            );
            let health = sc.field("health")?.as_arr()?;
            ensure!(!health.is_empty(), "scenario health must be non-empty");
            for h in health {
                h.field("stage")?.as_str()?;
                let rate = h.field("sat_per_sample")?.as_f64()?;
                ensure!(
                    rate.is_finite() && rate >= 0.0,
                    "sat_per_sample must be non-negative, got {rate}"
                );
                ensure!(
                    h.field("max_bits")?.as_usize()? <= 32,
                    "max_bits exceeds a raw word"
                );
                match h.field("headroom_bits")? {
                    Json::Null => {}
                    other => {
                        other.as_usize().context("headroom_bits")?;
                    }
                }
            }
        }
    }
    let mt = v.field("multi_tenant")?.as_arr()?;
    ensure!(!mt.is_empty(), "multi_tenant must be non-empty");
    let mut has_baseline = false;
    let mut has_sharded = false;
    let mut has_pipelined = false;
    for row in mt {
        let tenants = row.field("tenants")?.as_usize()?;
        let shards = row.field("shards")?.as_usize()?;
        ensure!(tenants >= 1 && shards >= 1, "bad multi_tenant row shape");
        has_baseline |= tenants == 1 && shards == 1;
        has_sharded |= tenants >= 8 && shards >= 2;
        row.field("batch")?.as_usize()?;
        row.field("batches_per_tenant")?.as_usize()?;
        row.field("precision")?.as_str()?;
        let pipelined = row.field("pipelined")?.as_bool()?;
        let agg = row.field("aggregate_samples_per_s")?.as_f64()?;
        ensure!(
            agg.is_finite() && agg > 0.0,
            "multi_tenant aggregate must be positive, got {agg}"
        );
        let speedup = row.field("speedup_over_single")?.as_f64()?;
        ensure!(
            speedup.is_finite() && speedup > 0.0,
            "speedup_over_single must be positive, got {speedup}"
        );
        match row.field("pipelined_over_serial")? {
            Json::Null => {}
            other => {
                ensure!(
                    pipelined,
                    "pipelined_over_serial on a serial multi_tenant row"
                );
                let r = other.as_f64()?;
                ensure!(
                    r.is_finite() && r > 0.0,
                    "pipelined_over_serial must be positive, got {r}"
                );
                has_pipelined = true;
            }
        }
        match row.field("fairness_spread")? {
            Json::Null => {}
            other => {
                let s = other.as_f64()?;
                ensure!(s >= 1.0, "fairness spread is slowest/fastest, got {s}");
            }
        }
    }
    ensure!(
        has_baseline,
        "multi_tenant needs a tenants=1/shards=1 baseline row"
    );
    ensure!(
        has_sharded,
        "multi_tenant needs a >=8-tenant row on >=2 shards"
    );
    ensure!(
        has_pipelined,
        "multi_tenant needs a pipelined row with pipelined_over_serial"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `run` toggles the process-global scalar-force flag for the
    /// scalar-vs-simd pairs; serialize the tests that invoke it so a
    /// concurrent run can never misattribute a row's `simd` flag.
    static BENCH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn smoke_opts() -> BenchOptions {
        BenchOptions {
            datasets: vec!["waveform".into()],
            tile: 64,
            lanes: 2,
            smoke: true,
            seed: 7,
        }
    }

    #[test]
    fn smoke_run_produces_valid_schema() {
        let _serial = BENCH_LOCK.lock().unwrap();
        let opts = smoke_opts();
        let report = run(&opts).unwrap();
        assert_eq!(report.configs.len(), 1);
        let cfg = &report.configs[0];
        assert_eq!(cfg.dataset, "waveform");
        assert_eq!((cfg.m, cfg.p, cfg.n), (32, 16, 8));
        // The full grid: 2 train f32 + 3 train fxp (per-sample +
        // scalar/simd tiled pair) + 2 forward f32 + 4 forward fxp
        // (per-sample + scalar/simd tiled pair + multilane).
        assert_eq!(cfg.points.len(), 11);
        assert!(cfg.points.iter().all(|p| p.samples_per_s > 0.0));
        // The scalar-vs-simd pairs: two fxp tiled rows per path, the
        // scalar half always with simd=false, and no f32 row ever
        // claims the vectorized dispatch.
        for path in ["train", "forward"] {
            let pair: Vec<_> = cfg
                .points
                .iter()
                .filter(|p| p.path == path && p.mode == "tiled" && p.precision != "f32")
                .collect();
            assert_eq!(pair.len(), 2, "{path} fxp tiled pair");
            assert!(!pair[0].simd, "{path} scalar half must come first");
            assert_eq!(pair[1].simd, crate::fxp::simd::enabled());
        }
        assert!(cfg
            .points
            .iter()
            .filter(|p| p.precision == "f32")
            .all(|p| !p.simd));
        // The simd speedup labels ride along whatever the feature set.
        for key in [
            "train_fxp_tiled_simd_over_scalar",
            "forward_fxp_tiled_simd_over_scalar",
        ] {
            let (_, ratio) = cfg
                .speedups
                .iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("missing speedup {key}"));
            assert!(ratio.is_finite() && *ratio > 0.0);
        }
        // The three stage-graph scenarios ride along per config.
        assert_eq!(cfg.scenarios.len(), 3);
        assert!(cfg.scenarios.iter().all(|s| s.samples_per_s > 0.0));
        // Every scenario carries at least one telemetry health row, and
        // fixed-point scenarios report real occupancy + headroom.
        assert!(cfg.scenarios.iter().all(|s| !s.health.is_empty()));
        let fxp = cfg
            .scenarios
            .iter()
            .find(|s| s.precision == "q4.12")
            .unwrap();
        assert!(fxp
            .health
            .iter()
            .any(|h| h.max_bits > 0 && h.headroom_bits.is_some()));
        assert!(cfg
            .scenarios
            .iter()
            .any(|s| s.stages == "rp:ternary/16,pca"));
        assert!(cfg
            .scenarios
            .iter()
            .any(|s| s.stages == "whiten:gha" && s.precision == "q4.12"));
        // The multi-tenant serving family: a 1×1 baseline plus sharded
        // rows, then the mixed-preset serial-vs-pipelined pair. Speedup
        // magnitudes depend on the host's core count and the test
        // harness's own CPU contention, so assert structure and sanity,
        // not the ratio — the real numbers ride the JSON.
        assert_eq!(report.multi_tenant.len(), 5);
        let base = &report.multi_tenant[0];
        assert_eq!((base.tenants, base.shards), (1, 1));
        assert!((base.speedup_over_single - 1.0).abs() < 1e-9);
        assert!(report
            .multi_tenant
            .iter()
            .any(|mt| mt.tenants >= 8 && mt.shards >= 2));
        for mt in &report.multi_tenant {
            assert!(mt.aggregate_samples_per_s > 0.0);
            assert!(mt.speedup_over_single.is_finite() && mt.speedup_over_single > 0.0);
            assert!(mt.p50_ns.is_some() && mt.p99_ns.is_some());
        }
        // The pair: same shape and workload, serial first (no ratio),
        // pipelined second carrying pipelined_over_serial.
        let mixed_serial = &report.multi_tenant[3];
        let mixed_piped = &report.multi_tenant[4];
        assert_eq!(mixed_serial.precision, "mixed");
        assert!(!mixed_serial.pipelined);
        assert!(mixed_serial.pipelined_over_serial.is_none());
        assert_eq!(mixed_piped.precision, "mixed");
        assert!(mixed_piped.pipelined);
        let ratio = mixed_piped.pipelined_over_serial.unwrap();
        assert!(ratio.is_finite() && ratio > 0.0);
        assert!(report.multi_tenant[..3]
            .iter()
            .all(|mt| mt.precision == "f32" && !mt.pipelined));
        let json = to_json(&opts, &report);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        validate(&parsed).unwrap();
        let table = render(&opts, &report);
        assert!(table.contains("multilane"), "{table}");
        assert!(table.contains("scenario"), "{table}");
        assert!(table.contains("multi-tenant serving"), "{table}");
    }

    #[test]
    fn validate_rejects_drifted_schema() {
        let _serial = BENCH_LOCK.lock().unwrap();
        let opts = smoke_opts();
        let report = run(&opts).unwrap();
        let good = to_json(&opts, &report);
        // Drop a required field.
        let mut map = good.as_obj().unwrap().clone();
        map.remove("configs");
        assert!(validate(&Json::Obj(map)).is_err());
        // Wrong experiment tag.
        let mut map = good.as_obj().unwrap().clone();
        map.insert("experiment".into(), Json::str("something_else"));
        assert!(validate(&Json::Obj(map)).is_err());
        // Empty configs.
        let mut map = good.as_obj().unwrap().clone();
        map.insert("configs".into(), Json::Arr(vec![]));
        assert!(validate(&Json::Obj(map)).is_err());
        // Stale schema version (pre-pipeline writers must not validate).
        let mut map = good.as_obj().unwrap().clone();
        map.insert("schema_version".into(), Json::num(5.0));
        assert!(validate(&Json::Obj(map)).is_err());
        // Missing or empty multi_tenant family.
        let mut map = good.as_obj().unwrap().clone();
        map.remove("multi_tenant");
        assert!(validate(&Json::Obj(map)).is_err());
        let mut map = good.as_obj().unwrap().clone();
        map.insert("multi_tenant".into(), Json::Arr(vec![]));
        assert!(validate(&Json::Obj(map)).is_err());
    }
}
