//! End-to-end driver (DESIGN.md §5, experiment T1): the full paper
//! protocol on the Waveform dataset through ALL THREE LAYERS —
//!
//!   Rust coordinator (streaming batcher + reconfig + metrics)
//!     → PJRT-executed AOT artifacts (JAX L2 + Pallas L1, compiled at
//!       build time; Python is NOT running now)
//!       → downstream 2×64 classifier (also via PJRT artifacts here)
//!
//! Regenerates Table I on the PJRT backend (falling back to native with
//! a warning if `make artifacts` has not run) and logs the convergence
//! trace + classifier loss curve that EXPERIMENTS.md records.
//!
//! ```text
//! cargo run --release --example waveform_train [-- --backend native]
//! ```

use dimred::config::Backend;
use dimred::runtime::{Runtime, Tensor};
use dimred::rng::{Pcg64, RngExt};
use dimred::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let requested = Backend::parse(&args.str_or("backend", "pjrt"))?;
    let artifact_dir = args.str_or("artifacts", "artifacts");
    let epochs = args.usize_or("epochs", 8)?;
    let seed = args.u64_or("seed", 2018)?;

    let runtime = match requested {
        Backend::Pjrt => match Runtime::load(Path::new(&artifact_dir)) {
            Ok(rt) => {
                println!("# PJRT platform: {} ({} artifacts)", rt.platform(),
                         rt.manifest().artifacts.len());
                Some(rt)
            }
            Err(e) => {
                eprintln!("warning: {e:#}\nfalling back to the native backend");
                None
            }
        },
        Backend::Native => None,
    };
    let backend = if runtime.is_some() {
        Backend::Pjrt
    } else {
        Backend::Native
    };

    println!("# Table I — end-to-end on the {} backend", match backend {
        Backend::Pjrt => "PJRT (AOT artifacts)",
        Backend::Native => "native Rust",
    });
    let rows = dimred::experiments::table1::run(runtime.as_ref(), backend, epochs, seed)?;
    println!("{}", dimred::experiments::table1::render(&rows));
    dimred::experiments::table1::check_shape(&rows, 13.0)?;
    println!("shape criteria (DESIGN.md §5): OK\n");

    // ---- classifier training THROUGH PJRT, with a logged loss curve —
    // proves the MLP artifacts compose with the DR artifacts.
    if let Some(rt) = &runtime {
        println!("# classifier-on-PJRT loss curve (n=8 features, waveform)");
        let mut data = dimred::datasets::waveform::WaveformConfig {
            seed,
            ..dimred::datasets::waveform::WaveformConfig::paper()
        }
        .generate();
        data.standardize();
        // Reduce with the proposed pipeline (native transform of the
        // PJRT-trained state would be equivalent; keep it simple).
        let cfg = dimred::config::ExperimentConfig {
            mode: dimred::config::PipelineMode::RpEasi,
            backend: Backend::Pjrt,
            intermediate_dim: 16,
            output_dim: 8,
            epochs,
            seed,
            train_classifier: false,
            ..Default::default()
        };
        let report = dimred::coordinator::TrainingService::new(cfg, Some(rt)).run(&data)?;
        let mut reduced = data.map_features(&{
            // effective pipeline = B_eff · R
            let eff = report.separation.clone();
            let r = report.rp.clone().unwrap();
            eff.matmul(&r)
        });
        reduced.standardize();

        // SGD through the mlp_train artifact, batch 32.
        let (d, h, c, b) = (8usize, 64usize, 3usize, 32usize);
        let name = format!("mlp_train_in{d}_h{h}_c{c}_b{b}");
        let mut rng = Pcg64::seed_stream(seed, 0x4D4C_5057);
        let he = |fan_in: usize| (2.0f64 / fan_in as f64).sqrt();
        let mut params = vec![
            Tensor::new(vec![h, d], (0..h * d).map(|_| (rng.next_gaussian() * he(d)) as f32).collect()),
            Tensor::new(vec![h], vec![0.0; h]),
            Tensor::new(vec![h, h], (0..h * h).map(|_| (rng.next_gaussian() * he(h)) as f32).collect()),
            Tensor::new(vec![h], vec![0.0; h]),
            Tensor::new(vec![c, h], (0..c * h).map(|_| (rng.next_gaussian() * he(h)) as f32).collect()),
            Tensor::new(vec![c], vec![0.0; c]),
        ];
        let mut vels: Vec<Tensor> = params
            .iter()
            .map(|t| Tensor::new(t.shape.clone(), vec![0.0; t.data.len()]))
            .collect();
        let ntrain = reduced.train_x.rows_count();
        let mut order: Vec<usize> = (0..ntrain).collect();
        let mlp_epochs = 20usize;
        for epoch in 0..mlp_epochs {
            rng.shuffle(&mut order);
            let mut loss_sum = 0.0f64;
            let mut steps = 0usize;
            for chunk in order.chunks(b) {
                if chunk.len() < b {
                    continue; // fixed-shape artifact; drop the remainder
                }
                let mut xs = Vec::with_capacity(b * d);
                let mut onehot = vec![0.0f32; b * c];
                for (i, &idx) in chunk.iter().enumerate() {
                    xs.extend_from_slice(reduced.train_x.row(idx));
                    onehot[i * c + reduced.train_y[idx]] = 1.0;
                }
                let mut inputs = params.clone();
                inputs.extend(vels.clone());
                inputs.push(Tensor::new(vec![b, d], xs));
                inputs.push(Tensor::new(vec![b, c], onehot));
                inputs.push(Tensor::scalar(0.05));
                inputs.push(Tensor::scalar(0.9));
                let outs = rt.execute(&name, &inputs)?;
                for (k, slot) in [0usize, 2, 4, 6, 8, 10].iter().enumerate() {
                    params[k] = outs[*slot].clone();
                    vels[k] = outs[slot + 1].clone();
                }
                loss_sum += outs[12].data[0] as f64;
                steps += 1;
            }
            if epoch % 2 == 0 || epoch + 1 == mlp_epochs {
                println!("loss epoch {epoch:>2}: {:.4}", loss_sum / steps as f64);
            }
        }

        // Evaluate via the mlp_predict artifact (batch 1 to cover the
        // whole test set without padding).
        let pred_name = format!("mlp_predict_in{d}_h{h}_c{c}_b1");
        let mut correct = 0usize;
        let ntest = reduced.test_x.rows_count();
        for i in 0..ntest {
            let mut inputs = params.clone();
            inputs.push(Tensor::new(vec![1, d], reduced.test_x.row(i).to_vec()));
            let logits = rt.execute1(&pred_name, &inputs)?;
            let mut best = 0;
            for k in 1..c {
                if logits.data[k] > logits.data[best] {
                    best = k;
                }
            }
            if best == reduced.test_y[i] {
                correct += 1;
            }
        }
        println!(
            "PJRT-classifier test accuracy: {:.1}%  ({} samples)",
            100.0 * correct as f64 / ntest as f64,
            ntest
        );
    }
    println!("waveform_train OK");
    Ok(())
}
