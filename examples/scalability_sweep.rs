//! §V.C scaling claim: "the amount of savings will be proportional to
//! m/p". Sweeps input dimensionality m and intermediate dimensionality
//! p, printing the modelled DSP/ALM/register cost of plain EASI vs the
//! proposed RP+EASI cascade and the resulting saving factor — the
//! paper's scalability argument as a reproducible series.
//!
//! ```text
//! cargo run --release --example scalability_sweep [-- --output-dim 8]
//! ```

use dimred::hwmodel::{table_ii, HwConfig, ARRIA10_CAPACITY};
use dimred::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let n = args.usize_or("output-dim", 8)?;

    println!("Scalability sweep (n = {n}): EASI(m→n) vs RP(m→p)+EASI(p→n)");
    println!(
        "{:>5} {:>5} | {:>8} {:>8} {:>7} | {:>8} {:>8} | {:>6} {:>6} {:>9}",
        "m", "p", "DSP", "DSP'", "m/p", "ALM", "ALM'", "save", "fits?", "fits'?"
    );
    for m in [32usize, 64, 128, 256, 512, 1024] {
        for p in [m / 2, m / 4] {
            if p < n {
                continue;
            }
            let rows = table_ii(&[HwConfig::easi(m, n), HwConfig::rp_easi(m, p, n)]);
            let saving = rows[0].dsps as f64 / rows[1].dsps as f64;
            let fits = |dsps: u64, alms: u64| dsps <= ARRIA10_CAPACITY.dsps && alms <= ARRIA10_CAPACITY.alms;
            println!(
                "{:>5} {:>5} | {:>8} {:>8} {:>7.2} | {:>8} {:>8} | {:>5.2}x {:>6} {:>9}",
                m,
                p,
                rows[0].dsps,
                rows[1].dsps,
                m as f64 / p as f64,
                rows[0].alms,
                rows[1].alms,
                saving,
                fits(rows[0].dsps, rows[0].alms),
                fits(rows[1].dsps, rows[1].alms),
            );
        }
    }
    println!(
        "\nArria-10 capacity: {} DSPs / {} ALMs — the cascade pushes the",
        ARRIA10_CAPACITY.dsps, ARRIA10_CAPACITY.alms
    );
    println!("feasible input dimensionality up by ≈ m/p, the paper's §V.C claim.");
    println!("scalability_sweep OK");
    Ok(())
}
