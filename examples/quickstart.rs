//! Quickstart: the paper's Fig. 2 in action — whiten, then rotate.
//!
//! Mixes three independent sub-Gaussian sources through a random matrix,
//! then recovers them with the composed DR unit (GHA whitening + EASI
//! rotation). Prints the whiteness of the outputs and the Amari
//! separation index of the global system — the standard "did ICA work"
//! metrics. Run with:
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use dimred::linalg::{amari_index, whiteness_error, Mat};
use dimred::pipeline::{DrUnit, DrUnitConfig};
use dimred::rng::{Pcg64, RngExt};

fn main() {
    // --- generate: x = A s, s independent uniform (sub-Gaussian) ----
    let (n_src, samples) = (3usize, 8000usize);
    let mut rng = Pcg64::seed(42);
    let sources = Mat::from_fn(samples, n_src, |_, _| {
        (rng.next_f32() * 2.0 - 1.0) * 3f32.sqrt() // unit variance
    });
    let mixing = Mat::from_fn(n_src, n_src, |_, _| rng.next_gaussian() as f32);
    let x = mixing.apply_rows(&sources);
    println!("mixed {samples} samples of {n_src} independent sources");
    println!("whiteness of mixtures: {:.3}", whiteness_error(&x));

    // --- train: streaming whiten + rotate (paper Fig. 2) ------------
    let mut unit = DrUnit::new(DrUnitConfig {
        input_dim: n_src,
        output_dim: n_src,
        rot_warmup: 1000,
        ..Default::default()
    });
    for epoch in 0..6 {
        unit.step_rows(&x);
        let eff = unit.effective_matrix();
        let y = eff.apply_rows(&x);
        let p = eff.matmul(&mixing);
        println!(
            "epoch {epoch}: output whiteness {:.3}  amari index {:.3}",
            whiteness_error(&y),
            amari_index(&p),
        );
    }

    // --- verify ------------------------------------------------------
    let eff = unit.effective_matrix();
    let global = eff.matmul(&mixing);
    let idx = amari_index(&global);
    println!("\nglobal system B·A (≈ scaled permutation if separated):");
    for i in 0..n_src {
        let row: Vec<String> = (0..n_src)
            .map(|j| format!("{:>7.3}", global.get(i, j)))
            .collect();
        println!("  [{}]", row.join(" "));
    }
    println!("final amari index: {idx:.4}  (0 = perfect separation)");
    assert!(idx < 0.25, "separation failed");
    println!("quickstart OK");
}
