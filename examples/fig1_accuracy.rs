//! Regenerate the paper's Fig. 1 (a/b/c): classification accuracy vs
//! number of reduced features, four DR algorithms, three datasets.
//!
//! ```text
//! cargo run --release --example fig1_accuracy                  # all three
//! cargo run --release --example fig1_accuracy -- mnist --points 5
//! ```
//!
//! Datasets are the structural substitutes of DESIGN.md §7 (no network
//! access); the acceptance criterion is the relative *shape* of the
//! series, recorded in EXPERIMENTS.md.

use dimred::experiments::fig1;
use dimred::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let points = args.usize_or("points", 4)?;
    let seed = args.u64_or("seed", 2018)?;
    let which: Vec<String> = if args.positional.is_empty() {
        vec!["mnist".into(), "har".into(), "ads".into()]
    } else {
        args.positional.clone()
    };
    for ds in &which {
        let baseline = fig1::baseline_accuracy(ds, seed)?;
        let series = fig1::run(ds, points, seed)?;
        println!("{}", fig1::render(ds, &series));
        println!("no-DR baseline (full dimensionality): {baseline:.1}%\n");
    }
    println!("fig1_accuracy OK");
    Ok(())
}
