//! Regenerate the paper's Table II (FPGA resource cost) and Fig. 3
//! stage inventory from the calibrated Arria-10 model.
//!
//! ```text
//! cargo run --release --example table2_cost             # Table II
//! cargo run --release --example table2_cost -- --stages # Fig. 3 / Alg. 1
//! ```

use dimred::hwmodel::ops::easi_stage_ops;
use dimred::hwmodel::{
    paper_table_ii_configs, table_ii, Arria10Model, HwConfig, PipelineModel, PAPER_TABLE_II,
};
use dimred::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["stages"])?;
    if args.flag("stages") {
        print_stage_inventory(32, 8);
        return Ok(());
    }

    println!("Table II — Arria-10 resource model vs paper (m=32, n=8, fp32)");
    println!(
        "{:<26} {:>7} {:>8} {:>10}   {:>7} {:>8} {:>10}   {:>6}",
        "configuration", "DSPs", "ALMs", "reg bits", "paper", "paper", "paper", "Δmax"
    );
    let rows = table_ii(&paper_table_ii_configs());
    for (row, paper) in rows.iter().zip(PAPER_TABLE_II.iter()) {
        let label = match row.intermediate {
            Some(p) => HwConfig::rp_easi(row.input, p, row.output).label(),
            None => HwConfig::easi(row.input, row.output).label(),
        };
        let rel = |got: u64, want: u64| (got as f64 - want as f64).abs() / want as f64;
        let worst = rel(row.dsps, paper.0)
            .max(rel(row.alms, paper.1))
            .max(rel(row.register_bits, paper.2));
        println!(
            "{:<26} {:>7} {:>8} {:>10}   {:>7} {:>8} {:>10}   {:>5.1}%",
            label,
            row.dsps,
            row.alms,
            row.register_bits,
            paper.0,
            paper.1,
            paper.2,
            worst * 100.0
        );
    }
    let saving = rows[0].dsps as f64 / rows[1].dsps as f64;
    println!("\nDSP saving factor: {saving:.2}× (paper: {:.2}×, claim: ∝ m/p = 2×)",
             PAPER_TABLE_II[0].0 as f64 / PAPER_TABLE_II[1].0 as f64);

    // Timing corner (paper §V.C last paragraph).
    let timing = PipelineModel::default();
    for cfg in [HwConfig::easi(32, 8), HwConfig::rp_easi(32, 16, 8)] {
        let t = timing.timing(&cfg);
        println!(
            "{:<26} f_clk {:.2} MHz   latency {} cycles ({:.0} ns)",
            cfg.label(),
            t.f_clk_hz / 1e6,
            t.latency_cycles,
            t.latency_ns
        );
    }
    println!("table2_cost OK");
    Ok(())
}

fn print_stage_inventory(m: usize, n: usize) {
    println!("Fig. 3 / Alg. 1 stage inventory, EASI {m}→{n} (multipliers, adders):");
    let names = [
        "1: y = Bx",
        "2: g(y) = y³",
        "3: F = yyᵀ−I + gyᵀ−ygᵀ",
        "4: F·B (relative gradient)",
        "5: B ← B − μ(FB)",
    ];
    let mut tm = 0;
    let mut ta = 0;
    for (stage, name) in names.iter().enumerate() {
        let (mults, adds) = easi_stage_ops(m, n, stage + 1);
        tm += mults;
        ta += adds;
        println!("  stage {:<30} {:>6} mult {:>6} add", name, mults, adds);
    }
    println!("  total {:>36} mult {:>6} add  → O(m·n²) dominated by stage 4", tm, ta);
    let model = Arria10Model::paper_calibrated();
    let r = model.cost(&HwConfig::easi(m, n));
    println!(
        "  mapped: {} DSPs, {} ALMs, {} register bits ({:.0}% of Arria-10 DSPs)",
        r.dsps,
        r.alms,
        r.register_bits,
        r.dsp_utilisation * 100.0
    );
}
