//! Real-time reconfigurability demo (paper §IV): one training service,
//! datapath mode swapped mid-stream without losing state.
//!
//! Starts the waveform stream in PCA-whitening mode (HOS term muxed
//! out), then switches to full ICA after 8000 samples — on the PJRT
//! backend this literally swaps the compiled executable while the W/λ̂/U
//! state rides through, which is the software analogue of the paper's
//! control-signal mux.
//!
//! ```text
//! cargo run --release --example reconfigure_demo [-- --backend pjrt]
//! ```

use dimred::config::{Backend, ExperimentConfig, PipelineMode};
use dimred::coordinator::{ReconfigCommand, TrainingService};
use dimred::datasets::waveform::WaveformConfig;
use dimred::runtime::Runtime;
use dimred::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let backend = Backend::parse(&args.str_or("backend", "native"))?;
    let runtime = match backend {
        Backend::Pjrt => Some(Runtime::load(Path::new(&args.str_or(
            "artifacts",
            "artifacts",
        )))?),
        Backend::Native => None,
    };

    let mut data = WaveformConfig::paper().generate();
    data.standardize();

    let cfg = ExperimentConfig {
        mode: PipelineMode::PcaWhiten, // start as a whitening engine
        backend,
        input_dim: 32,
        intermediate_dim: 16,
        output_dim: 16,
        epochs: 4,
        rot_warmup: 0,
        train_classifier: true,
        mlp_epochs: 20,
        ..Default::default()
    };
    let mut svc = TrainingService::new(cfg, runtime.as_ref());
    svc.schedule_reconfig(ReconfigCommand {
        after_samples: 8000,
        mode: PipelineMode::Easi, // flip the HOS mux on
    });
    let report = svc.run(&data)?;

    println!("# {}", report.metrics.summary());
    for (at, mode) in &report.metrics.reconfigurations {
        println!("reconfigured to '{mode}' after {at} samples (state preserved)");
    }
    println!("convergence trace (samples, update magnitude):");
    for (s, m) in report
        .metrics
        .convergence_trace
        .iter()
        .step_by(4.max(report.metrics.convergence_trace.len() / 10))
    {
        println!("  {s:>6}  {m:.4}");
    }
    if let Some(acc) = report.test_accuracy {
        println!("test accuracy after mid-stream reconfiguration: {:.1}%", acc * 100.0);
    }
    assert_eq!(report.metrics.reconfigurations.len(), 1);
    println!("reconfigure_demo OK");
    Ok(())
}
